//! Structural operations on CSR matrices: transpose, addition, triangular
//! extraction, row permutation, and a reference row-wise SpGEMM used as the
//! oracle for KKMEM.

use super::csr::{Csr, Idx};

/// Transpose (used to form `P = Rᵀ` in the multigrid triple product).
pub fn transpose(m: &Csr) -> Csr {
    let mut counts = vec![0usize; m.ncols + 1];
    for &c in &m.entries {
        counts[c as usize + 1] += 1;
    }
    for j in 0..m.ncols {
        counts[j + 1] += counts[j];
    }
    let rowmap = counts.clone();
    let mut cursor = counts;
    let mut entries = vec![0 as Idx; m.nnz()];
    let mut values = vec![0.0f64; m.nnz()];
    for i in 0..m.nrows {
        let (cols, vals) = m.row(i);
        for (&c, &v) in cols.iter().zip(vals) {
            let pos = cursor[c as usize];
            cursor[c as usize] += 1;
            entries[pos] = i as Idx;
            values[pos] = v;
        }
    }
    Csr::new(m.ncols, m.nrows, rowmap, entries, values)
}

/// C = A + B (same shape), merging sorted or unsorted rows.
pub fn spadd(a: &Csr, b: &Csr) -> Csr {
    assert_eq!((a.nrows, a.ncols), (b.nrows, b.ncols), "spadd shape mismatch");
    let mut rowmap = vec![0usize; a.nrows + 1];
    let mut entries: Vec<Idx> = Vec::with_capacity(a.nnz() + b.nnz());
    let mut values: Vec<f64> = Vec::with_capacity(a.nnz() + b.nnz());
    let mut acc: std::collections::BTreeMap<Idx, f64> = std::collections::BTreeMap::new();
    for i in 0..a.nrows {
        acc.clear();
        let (ca, va) = a.row(i);
        for (&c, &v) in ca.iter().zip(va) {
            *acc.entry(c).or_insert(0.0) += v;
        }
        let (cb, vb) = b.row(i);
        for (&c, &v) in cb.iter().zip(vb) {
            *acc.entry(c).or_insert(0.0) += v;
        }
        for (&c, &v) in &acc {
            entries.push(c);
            values.push(v);
        }
        rowmap[i + 1] = entries.len();
    }
    Csr::new(a.nrows, a.ncols, rowmap, entries, values)
}

/// Strictly-lower-triangular part (diagonal excluded) — the `L` of the
/// triangle-counting kernel.
pub fn lower_triangle(m: &Csr) -> Csr {
    assert_eq!(m.nrows, m.ncols, "lower_triangle needs square input");
    let mut rowmap = vec![0usize; m.nrows + 1];
    let mut entries = Vec::new();
    let mut values = Vec::new();
    for i in 0..m.nrows {
        let (cols, vals) = m.row(i);
        for (&c, &v) in cols.iter().zip(vals) {
            if (c as usize) < i {
                entries.push(c);
                values.push(v);
            }
        }
        rowmap[i + 1] = entries.len();
    }
    Csr::new(m.nrows, m.ncols, rowmap, entries, values)
}

/// Symmetric permutation `P·M·Pᵀ` given `perm[new] = old`
/// (row `new` of the result is row `perm[new]` of `m`, columns relabelled
/// by the inverse). Used for the degree-sort preprocessing of triangle
/// counting.
pub fn permute_symmetric(m: &Csr, perm: &[usize]) -> Csr {
    assert_eq!(m.nrows, m.ncols);
    assert_eq!(perm.len(), m.nrows);
    let mut inv = vec![0usize; perm.len()];
    for (new, &old) in perm.iter().enumerate() {
        inv[old] = new;
    }
    let mut rowmap = vec![0usize; m.nrows + 1];
    let mut entries = Vec::with_capacity(m.nnz());
    let mut values = Vec::with_capacity(m.nnz());
    for new_i in 0..m.nrows {
        let old_i = perm[new_i];
        let (cols, vals) = m.row(old_i);
        let mut row: Vec<(Idx, f64)> = cols
            .iter()
            .zip(vals)
            .map(|(&c, &v)| (inv[c as usize] as Idx, v))
            .collect();
        row.sort_by_key(|&(c, _)| c);
        for (c, v) in row {
            entries.push(c);
            values.push(v);
        }
        rowmap[new_i + 1] = entries.len();
    }
    Csr::new(m.nrows, m.ncols, rowmap, entries, values)
}

/// Reference row-wise SpGEMM via a BTreeMap accumulator — the correctness
/// oracle for KKMEM (slow but obviously right).
pub fn spgemm_reference(a: &Csr, b: &Csr) -> Csr {
    assert_eq!(a.ncols, b.nrows, "spgemm shape mismatch: {}x{} * {}x{}",
        a.nrows, a.ncols, b.nrows, b.ncols);
    let mut rowmap = vec![0usize; a.nrows + 1];
    let mut entries: Vec<Idx> = Vec::new();
    let mut values: Vec<f64> = Vec::new();
    let mut acc: std::collections::BTreeMap<Idx, f64> = std::collections::BTreeMap::new();
    for i in 0..a.nrows {
        acc.clear();
        let (acols, avals) = a.row(i);
        for (&k, &av) in acols.iter().zip(avals) {
            let (bcols, bvals) = b.row(k as usize);
            for (&j, &bv) in bcols.iter().zip(bvals) {
                *acc.entry(j).or_insert(0.0) += av * bv;
            }
        }
        for (&c, &v) in &acc {
            entries.push(c);
            values.push(v);
        }
        rowmap[i + 1] = entries.len();
    }
    Csr::new(a.nrows, b.ncols, rowmap, entries, values)
}

/// Number of scalar multiply-adds a row-wise SpGEMM performs:
/// `Σ_i Σ_{k∈A(i,:)} nnz(B(k,:))`. The paper's GFLOP counts are `2×` this.
pub fn spgemm_flops(a: &Csr, b: &Csr) -> u64 {
    let mut mults: u64 = 0;
    for &k in &a.entries {
        mults += b.row_len(k as usize) as u64;
    }
    2 * mults
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::dense::Dense;

    fn sample() -> Csr {
        Csr::new(
            3,
            3,
            vec![0, 2, 3, 5],
            vec![0, 2, 1, 0, 2],
            vec![1.0, 2.0, 3.0, 4.0, 5.0],
        )
    }

    #[test]
    fn transpose_involution() {
        let m = sample();
        let tt = transpose(&transpose(&m));
        assert!(m.approx_eq(&tt, 0.0));
    }

    #[test]
    fn transpose_matches_dense() {
        let m = sample();
        let t = transpose(&m);
        let d = Dense::from(&m);
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(t.get(j, i), d.get(i, j));
            }
        }
    }

    #[test]
    fn spadd_matches_dense() {
        let a = sample();
        let b = transpose(&sample());
        let c = spadd(&a, &b);
        let dc = Dense::from(&c);
        let mut expect = Dense::from(&a);
        let db = Dense::from(&b);
        for i in 0..3 {
            for j in 0..3 {
                expect.add(i, j, db.get(i, j));
            }
        }
        assert!(dc.approx_eq(&expect, 1e-12));
    }

    #[test]
    fn lower_triangle_strict() {
        let l = lower_triangle(&sample());
        for i in 0..3 {
            let (cols, _) = l.row(i);
            assert!(cols.iter().all(|&c| (c as usize) < i));
        }
        assert_eq!(l.get(2, 0), 4.0);
        assert_eq!(l.get(0, 0), 0.0);
    }

    #[test]
    fn spgemm_reference_matches_dense() {
        let a = sample();
        let b = transpose(&sample());
        let c = spgemm_reference(&a, &b);
        c.validate().unwrap();
        let expect = Dense::from(&a).matmul(&Dense::from(&b));
        assert!(Dense::from(&c).approx_eq(&expect, 1e-12));
    }

    #[test]
    fn spgemm_identity() {
        let a = sample();
        let i = Csr::identity(3);
        assert!(spgemm_reference(&a, &i).approx_eq(&a, 1e-12));
        assert!(spgemm_reference(&i, &a).approx_eq(&a, 1e-12));
    }

    #[test]
    fn flops_count() {
        let a = sample();
        let i = Csr::identity(3);
        // Each of A's 5 entries hits a length-1 row of I: 5 mults = 10 flops.
        assert_eq!(spgemm_flops(&a, &i), 10);
    }

    #[test]
    fn permute_symmetric_preserves_structure() {
        let m = sample();
        let perm = vec![2usize, 0, 1];
        let p = permute_symmetric(&m, &perm);
        p.validate().unwrap();
        // p[new_i][new_j] == m[perm[new_i]][perm[new_j]]
        let mut inv = vec![0usize; 3];
        for (n, &o) in perm.iter().enumerate() {
            inv[o] = n;
        }
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(p.get(inv[i], inv[j]), m.get(i, j));
            }
        }
        // Identity permutation is a no-op.
        let idp = permute_symmetric(&m, &[0, 1, 2]);
        assert!(idp.approx_eq(&m, 0.0));
    }
}
