//! The masked compressed triangle-counting kernel. For each row `i` of
//! `L`: load the compressed row `i` as a bitmask accumulator, then for
//! every neighbour `k ∈ L(i,:)` AND the compressed row `k` against it,
//! popcounting matches. There is no output matrix — the paper notes the
//! kernel "works only on the symbolic structure" — so the memory
//! behaviour is reads of `L` (stream) and of `compressed(L)` (irregular),
//! which is why DP places only the compressed matrix in HBM.

use crate::kkmem::compression::CompressedMatrix;
use crate::memory::alloc::{AllocError, Location};
use crate::memory::machine::{MemSim, MemTracer, RegionId};
use crate::sparse::csr::{Csr, Idx};
use crate::util::threadpool::parallel_for_dynamic;
use std::sync::atomic::{AtomicU64, Ordering};

const EMPTY: Idx = Idx::MAX;

/// Small open-addressing map block→mask for the row mask.
struct MaskMap {
    mask: usize,
    keys: Vec<Idx>,
    vals: Vec<u32>,
    occupied: Vec<u32>,
}

impl MaskMap {
    fn new(capacity: usize) -> Self {
        let cap = (capacity * 2).next_power_of_two().max(16);
        Self { mask: cap - 1, keys: vec![EMPTY; cap], vals: vec![0; cap], occupied: Vec::new() }
    }

    fn ensure(&mut self, capacity: usize) {
        let need = (capacity * 2).next_power_of_two().max(16);
        if need > self.keys.len() {
            *self = Self::new(capacity);
        }
    }

    #[inline]
    fn slot_of(&self, block: Idx) -> usize {
        let mut slot = (block.wrapping_mul(2654435761)) as usize & self.mask;
        loop {
            let k = self.keys[slot];
            if k == block || k == EMPTY {
                return slot;
            }
            slot = (slot + 1) & self.mask;
        }
    }

    #[inline]
    fn or_insert(&mut self, block: Idx, bits: u32) {
        let slot = self.slot_of(block);
        if self.keys[slot] == EMPTY {
            self.keys[slot] = block;
            self.vals[slot] = bits;
            self.occupied.push(slot as u32);
        } else {
            self.vals[slot] |= bits;
        }
    }

    /// AND lookup: bits of `block` present in the mask.
    #[inline]
    fn lookup(&self, block: Idx) -> u32 {
        let slot = self.slot_of(block);
        if self.keys[slot] == block {
            self.vals[slot]
        } else {
            0
        }
    }

    fn clear(&mut self) {
        for &s in &self.occupied {
            self.keys[s as usize] = EMPTY;
            self.vals[s as usize] = 0;
        }
        self.occupied.clear();
    }
}

/// Count triangles for rows `[lo, hi)` of `L` (generic over tracing).
#[allow(clippy::too_many_arguments)]
fn count_rows<T: MemTracer>(
    t: &mut T,
    l: &Csr,
    lc: &CompressedMatrix,
    lo: usize,
    hi: usize,
    map: &mut MaskMap,
    l_regions: (RegionId, RegionId),
    lc_regions: (RegionId, RegionId, RegionId),
    mask_region: RegionId,
) -> (u64, u64) {
    let (l_rowmap, l_entries) = l_regions;
    let (c_rowmap, c_blocks, c_masks) = lc_regions;
    let mut triangles = 0u64;
    let mut ops = 0u64;
    for i in lo..hi {
        // Build the mask from compressed row i.
        if T::ENABLED {
            t.read(c_rowmap, i as u64 * 8, 16);
        }
        let (iblocks, imasks) = lc.row(i);
        if T::ENABLED && !iblocks.is_empty() {
            let off = lc.rowmap[i] as u64;
            t.read(c_blocks, off * 4, iblocks.len() as u64 * 4);
            t.read(c_masks, off * 4, imasks.len() as u64 * 4);
        }
        map.ensure(iblocks.len());
        for (&b, &m) in iblocks.iter().zip(imasks) {
            if T::ENABLED {
                t.write(mask_region, (b as u64 * 8) % 4096, 8);
            }
            map.or_insert(b, m);
        }
        // Stream row i of L; AND each neighbour's compressed row.
        if T::ENABLED {
            t.read(l_rowmap, i as u64 * 8, 16);
        }
        let (neigh, _) = l.row(i);
        if T::ENABLED && !neigh.is_empty() {
            let off = l.rowmap[i] as u64;
            t.read(l_entries, off * 4, neigh.len() as u64 * 4);
        }
        let mut row_ops = 0u64;
        for &k in neigh {
            let k = k as usize;
            if T::ENABLED {
                t.read(c_rowmap, k as u64 * 8, 16);
            }
            let (kblocks, kmasks) = lc.row(k);
            if T::ENABLED && !kblocks.is_empty() {
                let off = lc.rowmap[k] as u64;
                t.read(c_blocks, off * 4, kblocks.len() as u64 * 4);
                t.read(c_masks, off * 4, kmasks.len() as u64 * 4);
            }
            for (&b, &m) in kblocks.iter().zip(kmasks) {
                triangles += (map.lookup(b) & m).count_ones() as u64;
                row_ops += 1;
            }
        }
        ops += row_ops;
        t.flops(2 * row_ops); // bitwise AND+popcount pairs
        map.clear();
    }
    (triangles, ops)
}

/// Native parallel triangle count over a degree-sorted lower-triangular
/// `L` and its compressed form.
pub fn tricount(l: &Csr, lc: &CompressedMatrix, threads: usize) -> u64 {
    let total = AtomicU64::new(0);
    // Dynamic scheduling: skewed graphs have wildly uneven rows.
    parallel_for_dynamic(l.nrows, threads, 64, |lo, hi, _| {
        let mut map = MaskMap::new(64);
        let mut t = crate::memory::machine::NullTracer;
        let (tri, _) =
            count_rows(&mut t, l, lc, lo, hi, &mut map, (0, 0), (0, 0, 0), 0);
        total.fetch_add(tri, Ordering::Relaxed);
    });
    total.load(Ordering::Relaxed)
}

/// Placement for the simulated kernel: where `L`, `compressed(L)` and the
/// mask accumulator live. The paper's DP puts only `compressed(L)` fast.
#[derive(Clone, Copy, Debug)]
pub struct TriPlacement {
    pub l: Location,
    pub lc: Location,
    pub mask: Location,
}

impl TriPlacement {
    pub fn uniform(loc: Location) -> Self {
        Self { l: loc, lc: loc, mask: loc }
    }
}

/// Simulated triangle count; returns (triangles, AND-ops).
pub fn tricount_sim(
    sim: &mut MemSim,
    l: &Csr,
    lc: &CompressedMatrix,
    placement: TriPlacement,
) -> Result<(u64, u64), AllocError> {
    let lc_deg = if lc.nrows == 0 { 1.0 } else { lc.nnz() as f64 / lc.nrows as f64 };
    sim.set_compute_efficiency(crate::memory::machine::lane_efficiency(
        l.avg_degree(),
        lc_deg,
    ));
    let l_rowmap = sim.alloc("L.rowmap", (l.nrows as u64 + 1) * 8, placement.l)?;
    let l_entries = sim.alloc("L.entries", (l.nnz() as u64).max(1) * 4, placement.l)?;
    let c_rowmap = sim.alloc("Lc.rowmap", (lc.nrows as u64 + 1) * 8, placement.lc)?;
    let c_blocks = sim.alloc("Lc.blocks", (lc.nnz() as u64).max(1) * 4, placement.lc)?;
    let c_masks = sim.alloc("Lc.masks", (lc.nnz() as u64).max(1) * 4, placement.lc)?;
    let mask_region = sim.alloc("mask", 4096, placement.mask)?;
    let mut map = MaskMap::new(64);
    let (tri, ops) = count_rows(
        sim,
        l,
        lc,
        0,
        l.nrows,
        &mut map,
        (l_rowmap, l_entries),
        (c_rowmap, c_blocks, c_masks),
        mask_region,
    );
    Ok((tri, ops))
}

/// Brute-force triangle counter for verification (O(n·d²)).
pub fn tricount_naive(adj: &Csr) -> u64 {
    let mut count = 0u64;
    for i in 0..adj.nrows {
        let (ni, _) = adj.row(i);
        for &j in ni {
            let j = j as usize;
            if j >= i {
                continue;
            }
            let (nj, _) = adj.row(j);
            for &k in nj {
                let k = k as usize;
                if k >= j {
                    continue;
                }
                if ni.contains(&(k as Idx)) {
                    count += 1;
                }
            }
        }
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::graphs::{erdos_renyi, graph500, social};
    use crate::gen::scale::ScaleFactor;
    use crate::memory::arch::{knl, KnlMode};
    use crate::tricount::lower::degree_sorted_lower;

    #[test]
    fn triangle_of_triangle_graph() {
        // K3: exactly one triangle.
        let mut coo = crate::sparse::Coo::new(3, 3);
        for (i, j) in [(0, 1), (1, 2), (0, 2)] {
            coo.push(i, j, 1.0);
            coo.push(j, i, 1.0);
        }
        let adj = coo.to_csr();
        let l = degree_sorted_lower(&adj);
        let lc = CompressedMatrix::compress(&l);
        assert_eq!(tricount(&l, &lc, 1), 1);
        assert_eq!(tricount_naive(&adj), 1);
    }

    #[test]
    fn k5_has_ten_triangles() {
        let adj = erdos_renyi(5, 1.1, 0); // p>1 => complete graph
        assert_eq!(adj.nnz(), 20);
        let l = degree_sorted_lower(&adj);
        let lc = CompressedMatrix::compress(&l);
        assert_eq!(tricount(&l, &lc, 2), 10);
    }

    #[test]
    fn matches_naive_on_random_graphs() {
        for seed in 0..5 {
            let adj = erdos_renyi(40, 0.25, seed);
            let expect = tricount_naive(&adj);
            let l = degree_sorted_lower(&adj);
            let lc = CompressedMatrix::compress(&l);
            assert_eq!(tricount(&l, &lc, 4), expect, "seed {seed}");
        }
    }

    #[test]
    fn matches_naive_on_skewed_graphs() {
        let adj = graph500(7, 8, 3);
        let expect = tricount_naive(&adj);
        let l = degree_sorted_lower(&adj);
        let lc = CompressedMatrix::compress(&l);
        assert_eq!(tricount(&l, &lc, 4), expect);
        let soc = social(7, 6, 0.4, 4);
        let l2 = degree_sorted_lower(&soc);
        let lc2 = CompressedMatrix::compress(&l2);
        assert_eq!(tricount(&l2, &lc2, 4), tricount_naive(&soc));
    }

    #[test]
    fn simulated_count_matches_native() {
        let adj = erdos_renyi(60, 0.2, 9);
        let l = degree_sorted_lower(&adj);
        let lc = CompressedMatrix::compress(&l);
        let expect = tricount(&l, &lc, 1);
        let arch = knl(KnlMode::Ddr, 64, ScaleFactor::default());
        let mut sim = MemSim::new(arch.spec);
        let (tri, ops) =
            tricount_sim(&mut sim, &l, &lc, TriPlacement::uniform(arch.default_loc)).unwrap();
        assert_eq!(tri, expect);
        assert!(ops > 0);
        let rep = sim.finish();
        assert!(rep.seconds > 0.0);
        assert!(rep.l2_miss_pct <= 100.0);
    }
}
