//! Preprocessing for triangle counting: degree-sort the vertices
//! (ascending), then keep the strictly lower triangle of the permuted
//! adjacency matrix. Degree ordering bounds the row lengths of `L` and is
//! what makes the masked SpGEMM fast on skewed graphs.

use crate::sparse::csr::Csr;
use crate::sparse::ops::{lower_triangle, permute_symmetric};

/// Permutation sorting vertices by ascending degree (stable on ties).
pub fn degree_permutation(adj: &Csr) -> Vec<usize> {
    let mut perm: Vec<usize> = (0..adj.nrows).collect();
    perm.sort_by_key(|&v| (adj.row_len(v), v));
    perm
}

/// Degree-sorted strictly-lower-triangular matrix of an undirected
/// adjacency matrix.
pub fn degree_sorted_lower(adj: &Csr) -> Csr {
    assert_eq!(adj.nrows, adj.ncols, "adjacency must be square");
    let perm = degree_permutation(adj);
    let permuted = permute_symmetric(adj, &perm);
    lower_triangle(&permuted)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::graphs::erdos_renyi;

    #[test]
    fn permutation_sorts_degrees() {
        let g = erdos_renyi(40, 0.2, 1);
        let perm = degree_permutation(&g);
        let degs: Vec<usize> = perm.iter().map(|&v| g.row_len(v)).collect();
        assert!(degs.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn lower_has_half_the_edges() {
        let g = erdos_renyi(30, 0.3, 2);
        let l = degree_sorted_lower(&g);
        assert_eq!(l.nnz() * 2, g.nnz(), "every undirected edge appears once");
        for i in 0..l.nrows {
            let (cols, _) = l.row(i);
            assert!(cols.iter().all(|&c| (c as usize) < i));
        }
    }

    #[test]
    fn triangle_count_invariant_under_permutation() {
        // The number of (i,j,k) cliques is permutation-invariant; spot
        // check via the naive counter in count.rs's tests.
        let g = erdos_renyi(25, 0.3, 3);
        let l = degree_sorted_lower(&g);
        l.validate().unwrap();
    }
}
