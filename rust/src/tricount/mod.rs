//! Linear-algebra triangle counting (§4.1.2) — the Wolf et al. method:
//! sort vertices by degree, take the strictly-lower-triangular `L` of the
//! permuted adjacency matrix, and count `Σ (L·L) ∘ L` using KKMEM's
//! compressed representation: for each row `i`, the mask is row `i` of
//! `L` itself, and each neighbour row `L(k,:)` is ANDed against it —
//! `L × compressed(L)` with a fused mask, no output matrix materialized.

pub mod count;
pub mod lower;

pub use count::{tricount, tricount_sim, TriPlacement};
pub use lower::degree_sorted_lower;
