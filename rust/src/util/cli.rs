//! A small declarative command-line parser (the offline build has no
//! `clap`). Supports subcommands, `--flag value`, `--flag=value`, boolean
//! switches, defaults, and auto-generated `--help` text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Specification of one flag.
#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// `None` => boolean switch; `Some(default)` => value flag.
    pub default: Option<String>,
    pub required: bool,
}

/// Declarative command spec: name, about text, flags.
#[derive(Clone, Debug, Default)]
pub struct CommandSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub flags: Vec<FlagSpec>,
}

impl CommandSpec {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, flags: Vec::new() }
    }

    /// Value flag with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            default: Some(default.to_string()),
            required: false,
        });
        self
    }

    /// Required value flag.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: None, required: true });
        self
    }

    /// Boolean switch (default false).
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: None, required: false });
        self
    }

    fn is_switch(&self, name: &str) -> Option<bool> {
        self.flags
            .iter()
            .find(|f| f.name == name)
            .map(|f| f.default.is_none() && !f.required)
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.name, self.about);
        let _ = writeln!(s, "\nFlags:");
        for f in &self.flags {
            let kind = if f.required {
                "<required>".to_string()
            } else if let Some(d) = &f.default {
                format!("[default: {d}]")
            } else {
                "[switch]".to_string()
            };
            let _ = writeln!(s, "  --{:<18} {} {}", f.name, f.help, kind);
        }
        s
    }

    /// Parse `args` (not including the subcommand itself).
    pub fn parse(&self, args: &[String]) -> Result<ParsedArgs, String> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut switches: BTreeMap<String, bool> = BTreeMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            let Some(stripped) = a.strip_prefix("--") else {
                return Err(format!("unexpected positional argument `{a}`\n{}", self.usage()));
            };
            let (name, inline_val) = match stripped.split_once('=') {
                Some((n, v)) => (n.to_string(), Some(v.to_string())),
                None => (stripped.to_string(), None),
            };
            match self.is_switch(&name) {
                None => {
                    return Err(format!("unknown flag `--{name}`\n{}", self.usage()));
                }
                Some(true) => {
                    if let Some(v) = inline_val {
                        let b: bool = v
                            .parse()
                            .map_err(|_| format!("flag --{name} expects true/false, got `{v}`"))?;
                        switches.insert(name, b);
                    } else {
                        switches.insert(name, true);
                    }
                    i += 1;
                }
                Some(false) => {
                    let val = if let Some(v) = inline_val {
                        v
                    } else {
                        i += 1;
                        args.get(i)
                            .cloned()
                            .ok_or_else(|| format!("flag --{name} needs a value"))?
                    };
                    values.insert(name, val);
                    i += 1;
                }
            }
        }
        // Defaults + required checks.
        for f in &self.flags {
            if let Some(d) = &f.default {
                values.entry(f.name.to_string()).or_insert_with(|| d.clone());
            } else if f.required && !values.contains_key(f.name) {
                return Err(format!("missing required flag --{}\n{}", f.name, self.usage()));
            }
        }
        Ok(ParsedArgs { values, switches })
    }
}

/// Result of parsing: typed getters.
#[derive(Clone, Debug)]
pub struct ParsedArgs {
    values: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
}

impl ParsedArgs {
    pub fn str(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} not declared/parsed"))
    }

    pub fn string(&self, name: &str) -> String {
        self.str(name).to_string()
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.switches.get(name).copied().unwrap_or(false)
    }

    pub fn usize(&self, name: &str) -> Result<usize, String> {
        self.str(name)
            .parse()
            .map_err(|e| format!("flag --{name}: expected integer: {e}"))
    }

    pub fn u64(&self, name: &str) -> Result<u64, String> {
        self.str(name)
            .parse()
            .map_err(|e| format!("flag --{name}: expected integer: {e}"))
    }

    pub fn f64(&self, name: &str) -> Result<f64, String> {
        self.str(name)
            .parse()
            .map_err(|e| format!("flag --{name}: expected float: {e}"))
    }

    /// Parse an enumerated flag through a `parse` function, reporting the
    /// allowed values on failure — e.g.
    /// `p.choice("engine", EngineKind::parse, "native|sim|pipelined")`.
    pub fn choice<T>(
        &self,
        name: &str,
        parse: impl Fn(&str) -> Option<T>,
        allowed: &str,
    ) -> Result<T, String> {
        let raw = self.str(name);
        parse(raw).ok_or_else(|| {
            format!("flag --{name}: expected one of {allowed}, got `{raw}`")
        })
    }

    /// Comma-separated list of values, e.g. `--sizes 1,2,4`.
    pub fn list(&self, name: &str) -> Vec<String> {
        self.str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().to_string())
            .collect()
    }

    pub fn usize_list(&self, name: &str) -> Result<Vec<usize>, String> {
        self.list(name)
            .iter()
            .map(|s| {
                s.parse()
                    .map_err(|e| format!("flag --{name}: expected integer list: {e}"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> CommandSpec {
        CommandSpec::new("test", "a test command")
            .opt("size", "8", "problem size")
            .req("input", "input path")
            .switch("verbose", "noisy output")
            .opt("names", "a,b", "name list")
    }

    fn argv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_and_defaults() {
        let p = spec().parse(&argv(&["--input", "f.mtx"])).unwrap();
        assert_eq!(p.str("size"), "8");
        assert_eq!(p.usize("size").unwrap(), 8);
        assert_eq!(p.str("input"), "f.mtx");
        assert!(!p.flag("verbose"));
    }

    #[test]
    fn parses_equals_form_and_switch() {
        let p = spec()
            .parse(&argv(&["--input=x", "--size=32", "--verbose"]))
            .unwrap();
        assert_eq!(p.usize("size").unwrap(), 32);
        assert!(p.flag("verbose"));
    }

    #[test]
    fn missing_required_is_error() {
        assert!(spec().parse(&argv(&["--size", "4"])).is_err());
    }

    #[test]
    fn unknown_flag_is_error() {
        let e = spec().parse(&argv(&["--input", "x", "--bogus", "1"])).unwrap_err();
        assert!(e.contains("unknown flag"));
    }

    #[test]
    fn list_parsing() {
        let p = spec()
            .parse(&argv(&["--input", "x", "--names", "p, q ,r"]))
            .unwrap();
        assert_eq!(p.list("names"), vec!["p", "q", "r"]);
    }

    #[test]
    fn help_is_err_with_usage() {
        let e = spec().parse(&argv(&["--help"])).unwrap_err();
        assert!(e.contains("a test command"));
        assert!(e.contains("--size"));
    }

    #[test]
    fn switch_with_explicit_value() {
        let p = spec().parse(&argv(&["--input", "x", "--verbose=false"])).unwrap();
        assert!(!p.flag("verbose"));
    }

    #[test]
    fn choice_parses_and_reports_allowed() {
        let parse = |s: &str| match s {
            "red" => Some(1u8),
            "blue" => Some(2u8),
            _ => None,
        };
        let p = spec().parse(&argv(&["--input", "red"])).unwrap();
        assert_eq!(p.choice("input", parse, "red|blue").unwrap(), 1);
        let p = spec().parse(&argv(&["--input", "green"])).unwrap();
        let e = p.choice("input", parse, "red|blue").unwrap_err();
        assert!(e.contains("red|blue") && e.contains("green"));
    }
}
