//! Minimal JSON writer (no `serde` offline). Only what the report/metrics
//! paths need: objects, arrays, strings, numbers, bools.

use std::fmt::Write as _;

/// A JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Self {
        Json::Obj(Vec::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Self {
        if let Json::Obj(ref mut kv) = self {
            kv.push((key.to_string(), val.into()));
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    pub fn render_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => write_num(out, *v),
            Json::Str(s) => write_str(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        let pad_in = "  ".repeat(indent + 1);
        match self {
            Json::Arr(xs) if !xs.is_empty() => {
                out.push_str("[\n");
                for (i, x) in xs.iter().enumerate() {
                    out.push_str(&pad_in);
                    x.write_pretty(out, indent + 1);
                    if i + 1 < xs.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(kv) if !kv.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in kv.iter().enumerate() {
                    out.push_str(&pad_in);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                    if i + 1 < kv.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_num(out: &mut String, v: f64) {
    if v.is_finite() {
        if v == v.trunc() && v.abs() < 1e15 {
            let _ = write!(out, "{}", v as i64);
        } else {
            let _ = write!(out, "{v}");
        }
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_object() {
        let j = Json::obj()
            .set("name", "laplace")
            .set("gflops", 3.68)
            .set("fits", true)
            .set("sizes", vec![1u64, 2, 4]);
        assert_eq!(
            j.render(),
            r#"{"name":"laplace","gflops":3.68,"fits":true,"sizes":[1,2,4]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::Num(42.0).render(), "42");
        assert_eq!(Json::Num(0.5).render(), "0.5");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn pretty_nests() {
        let j = Json::obj().set("a", Json::obj().set("b", 1u64));
        let p = j.render_pretty();
        assert!(p.contains("\n  \"a\": {\n"));
    }
}
