//! Substrate utilities built from scratch for the offline environment:
//! PRNG, CLI parsing, table/CSV output, statistics, thread pool, timing,
//! property-test framework, and a JSON writer.

pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
pub mod threadpool;
pub mod timer;
