//! A minimal property-based testing framework (no `proptest` crate in the
//! offline vendor set). Provides value generators over a seeded RNG, a
//! `check` runner that reports the failing seed, and integer/vec shrinking.
//!
//! Usage:
//! ```no_run
//! use mlmem_spgemm::util::proptest::{check, Gen};
//! check("addition commutes", 100, |g: &mut Gen| {
//!     let a = g.i64(-1000, 1000);
//!     let b = g.i64(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Xoshiro256;

/// Generator handle passed to each property iteration.
pub struct Gen {
    rng: Xoshiro256,
    pub case_seed: u64,
}

impl Gen {
    pub fn from_seed(seed: u64) -> Self {
        Self { rng: Xoshiro256::seed_from_u64(seed), case_seed: seed }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.usize_below(hi - lo + 1)
    }

    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.i64_range(lo, hi)
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.f64_range(lo, hi)
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.rng.bernoulli(p_true)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.rng.usize_below(xs.len())]
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, len: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..len).map(|_| self.usize(lo, hi)).collect()
    }

    /// Access the raw RNG (for generators that need more control).
    pub fn rng(&mut self) -> &mut Xoshiro256 {
        &mut self.rng
    }

    /// Random CSR with dimensions and degrees up to `max_n` / `max_deg`.
    pub fn csr(&mut self, max_n: usize, max_deg: usize) -> crate::sparse::Csr {
        let nrows = self.usize(1, max_n);
        let ncols = self.usize(1, max_n);
        let deg = self.usize(0, max_deg.min(ncols));
        crate::gen::rhs::random_csr(nrows, ncols, 0, deg, self.u64())
    }

    /// Random conformable pair `(A: m×k, B: k×n)` for SpGEMM properties.
    pub fn csr_pair(
        &mut self,
        max_n: usize,
        max_deg: usize,
    ) -> (crate::sparse::Csr, crate::sparse::Csr) {
        let m = self.usize(1, max_n);
        let k = self.usize(1, max_n);
        let n = self.usize(1, max_n);
        let da = self.usize(0, max_deg.min(k));
        let db = self.usize(0, max_deg.min(n));
        (
            crate::gen::rhs::random_csr(m, k, 0, da, self.u64()),
            crate::gen::rhs::random_csr(k, n, 0, db, self.u64()),
        )
    }
}

/// Run `prop` for `cases` iterations with distinct deterministic seeds.
/// On panic, re-raises with the failing case seed in the message so the
/// case can be replayed with [`replay`].
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
{
    let base = env_seed().unwrap_or(0x5EED_0000);
    for case in 0..cases {
        let seed = base ^ ((case as u64) << 32) ^ 0x9E37_79B9;
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::from_seed(seed);
            prop(&mut g);
        });
        if let Err(payload) = result {
            let msg = panic_message(&payload);
            panic!(
                "property `{name}` failed at case {case} (replay seed: {seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a property with an exact seed reported by [`check`].
pub fn replay<F>(seed: u64, mut prop: F)
where
    F: FnMut(&mut Gen),
{
    let mut g = Gen::from_seed(seed);
    prop(&mut g);
}

fn env_seed() -> Option<u64> {
    std::env::var("PROPTEST_SEED").ok().and_then(|s| {
        let s = s.trim();
        if let Some(hex) = s.strip_prefix("0x") {
            u64::from_str_radix(hex, 16).ok()
        } else {
            s.parse().ok()
        }
    })
}

fn panic_message(payload: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

/// Shrink a failing `usize` input to the smallest value that still fails.
/// `fails(x)` must be deterministic.
pub fn shrink_usize(mut failing: usize, fails: impl Fn(usize) -> bool) -> usize {
    debug_assert!(fails(failing));
    // Binary descent towards zero.
    loop {
        let mut advanced = false;
        for candidate in [failing / 2, failing.saturating_sub(1)] {
            if candidate < failing && fails(candidate) {
                failing = candidate;
                advanced = true;
                break;
            }
        }
        if !advanced {
            return failing;
        }
    }
}

/// Shrink a failing vector by removing chunks then individual elements.
pub fn shrink_vec<T: Clone>(mut failing: Vec<T>, fails: impl Fn(&[T]) -> bool) -> Vec<T> {
    debug_assert!(fails(&failing));
    let mut chunk = failing.len() / 2;
    while chunk >= 1 {
        let mut i = 0;
        while i + chunk <= failing.len() {
            let mut candidate = failing.clone();
            candidate.drain(i..i + chunk);
            if fails(&candidate) {
                failing = candidate;
                // stay at same i: more may be removable here
            } else {
                i += 1;
            }
        }
        chunk /= 2;
    }
    failing
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse twice is identity", 50, |g| {
            let len = g.usize(0, 20);
            let v = g.vec_usize(len, 0, 100);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            assert_eq!(v, w);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            check("always fails", 3, |_g| {
                panic!("boom");
            });
        });
        let msg = panic_message(&r.unwrap_err());
        assert!(msg.contains("replay seed"), "got: {msg}");
        assert!(msg.contains("boom"));
    }

    #[test]
    fn gen_ranges_respected() {
        check("usize range", 200, |g| {
            let v = g.usize(3, 9);
            assert!((3..=9).contains(&v));
            let f = g.f64(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        });
    }

    #[test]
    fn shrink_usize_finds_boundary() {
        // Fails iff >= 17; shrinker should land exactly on 17.
        let min = shrink_usize(1000, |x| x >= 17);
        assert_eq!(min, 17);
    }

    #[test]
    fn shrink_vec_minimizes() {
        // Fails iff the vec contains a 7 — minimal failing case is [7].
        let v = vec![1, 2, 7, 3, 7, 4];
        let min = shrink_vec(v, |xs| xs.contains(&7));
        assert_eq!(min, vec![7]);
    }

    #[test]
    fn csr_generators_produce_valid_conformable_matrices() {
        check("csr generators valid", 30, |g| {
            let m = g.csr(20, 5);
            m.validate().unwrap();
            let (a, b) = g.csr_pair(20, 5);
            a.validate().unwrap();
            b.validate().unwrap();
            assert_eq!(a.ncols, b.nrows);
        });
    }

    #[test]
    fn replay_is_deterministic() {
        let mut vals = Vec::new();
        replay(0xABCD, |g| vals.push(g.u64()));
        let mut vals2 = Vec::new();
        replay(0xABCD, |g| vals2.push(g.u64()));
        assert_eq!(vals, vals2);
    }
}
