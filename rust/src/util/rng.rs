//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so we carry our own generators:
//! [`SplitMix64`] for seeding and [`Xoshiro256`] (xoshiro256**) as the
//! workhorse. Both are well-studied, tiny, and reproducible across
//! platforms — reproducibility matters because every experiment in
//! EXPERIMENTS.md is keyed by an explicit seed.

/// SplitMix64: a fast 64-bit mixer, used to expand a single `u64` seed into
/// the 256-bit state xoshiro requires (as recommended by the xoshiro
/// authors).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: the general-purpose generator used by all workload
/// generators and the property-test framework.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 so that nearby integer seeds yield unrelated
    /// streams.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    fn rotl(x: u64, k: u32) -> u64 {
        x.rotate_left(k)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = Self::rotl(self.s[1].wrapping_mul(5), 7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = Self::rotl(self.s[3], 45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection method.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Bitmask rejection: unbiased and branch-light for our sizes.
        let mask = u64::MAX >> (bound - 1).leading_zeros().min(63);
        loop {
            let v = self.next_u64() & mask;
            if v < bound {
                return v;
            }
        }
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn usize_below(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)` using the top 53 bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    #[inline]
    pub fn i64_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.next_below((hi - lo + 1) as u64) as i64
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct values from {n}");
        // For small k relative to n, rejection with a scratch set is fast;
        // otherwise shuffle a prefix.
        if k * 4 <= n {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let v = self.usize_below(n);
                if seen.insert(v) {
                    out.push(v);
                }
            }
            out
        } else {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 1234567 (cross-checked against the
        // published C reference implementation).
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(a, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_seeds() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        let mut c = Xoshiro256::seed_from_u64(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn next_below_is_in_range_and_covers() {
        let mut r = Xoshiro256::seed_from_u64(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(9);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(11);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_has_no_duplicates() {
        let mut r = Xoshiro256::seed_from_u64(13);
        for &(n, k) in &[(100usize, 5usize), (100, 80), (10, 10), (1, 1)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&v| v < n));
        }
    }

    #[test]
    fn bernoulli_rate_roughly_matches() {
        let mut r = Xoshiro256::seed_from_u64(17);
        let hits = (0..10_000).filter(|_| r.bernoulli(0.3)).count();
        assert!((2700..3300).contains(&hits), "got {hits}");
    }
}
