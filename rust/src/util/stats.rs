//! Small statistics helpers used by the benchmark harness: the paper
//! reports the *median* of 20 repetitions with error bars showing the best
//! run, so we mirror exactly that.

/// Summary of a set of repeated measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub median: f64,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Summary::of on empty sample set");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        Self {
            n,
            median: median_sorted(&sorted),
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
        }
    }
}

fn median_sorted(sorted: &[f64]) -> f64 {
    let n = sorted.len();
    if n % 2 == 1 {
        sorted[n / 2]
    } else {
        0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
    }
}

/// Median of an unsorted slice.
pub fn median(samples: &[f64]) -> f64 {
    Summary::of(samples).median
}

/// Percentile (nearest-rank) of an unsorted slice; `p` in [0, 100].
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty());
    assert!((0.0..=100.0).contains(&p));
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
    let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Geometric mean — used when aggregating speedups across problems.
pub fn geomean(samples: &[f64]) -> f64 {
    assert!(!samples.is_empty());
    let log_sum: f64 = samples
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean needs positive samples, got {x}");
            x.ln()
        })
        .sum();
    (log_sum / samples.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_odd() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn summary_even() {
        let s = Summary::of(&[4.0, 1.0, 3.0, 2.0]);
        assert_eq!(s.median, 2.5);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[5.0]);
        assert_eq!(s.median, 5.0);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    fn stddev_known_value() {
        // Sample stddev of [2,4,4,4,5,5,7,9] is ~2.138 (population 2.0).
        let s = Summary::of(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.stddev - 2.138).abs() < 1e-3, "got {}", s.stddev);
    }

    #[test]
    fn percentile_bounds() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }
}
