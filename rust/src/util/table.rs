//! Aligned plain-text table printing plus CSV dumping — the bench harness
//! prints paper-shaped rows with this and archives CSVs under `reports/`.

use std::fmt::Write as _;
use std::io;
use std::path::Path;

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple table builder.
#[derive(Clone, Debug)]
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
    /// Key/value provenance pairs (arch, machine mode, input family …)
    /// carried into machine-readable exports so a `BENCH_*.json` row set
    /// is self-describing.
    context: Vec<(String, String)>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            aligns: headers
                .iter()
                .enumerate()
                // First column left (labels), the rest right (numbers).
                .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
                .collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
            context: Vec::new(),
        }
    }

    pub fn with_title(mut self, title: impl Into<String>) -> Self {
        self.title = Some(title.into());
        self
    }

    /// The table's display title, when one was set.
    pub fn title(&self) -> Option<&str> {
        self.title.as_deref()
    }

    /// Attach one provenance key/value pair (e.g. `("arch", "KNL ddr")`)
    /// for machine-readable exports; repeatable.
    pub fn with_context(mut self, key: impl Into<String>, value: impl Into<String>) -> Self {
        self.context.push((key.into(), value.into()));
        self
    }

    /// Provenance pairs attached via [`with_context`](Self::with_context).
    pub fn context(&self) -> &[(String, String)] {
        &self.context
    }

    pub fn align(mut self, col: usize, a: Align) -> Self {
        self.aligns[col] = a;
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Column headers (machine-readable exports).
    pub fn headers(&self) -> &[String] {
        &self.headers
    }

    /// Data rows (machine-readable exports).
    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    /// The table as a JSON array of row objects keyed by header — the
    /// `bench --json` export format. Cells that parse as finite numbers
    /// become JSON numbers; everything else stays a string.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::Arr(
            self.rows
                .iter()
                .map(|row| {
                    let mut obj = Json::obj();
                    for (h, c) in self.headers.iter().zip(row) {
                        obj = match c.parse::<f64>() {
                            Ok(v) if v.is_finite() => obj.set(h, v),
                            _ => obj.set(h, c.clone()),
                        };
                    }
                    obj
                })
                .collect(),
        )
    }

    /// Render with unicode-free ASCII separators.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            let _ = writeln!(out, "== {t} ==");
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                let cell = &cells[i];
                let pad = widths[i] - cell.len();
                match self.aligns[i] {
                    Align::Left => {
                        let _ = write!(line, " {}{} ", cell, " ".repeat(pad));
                    }
                    Align::Right => {
                        let _ = write!(line, " {}{} ", " ".repeat(pad), cell);
                    }
                }
                if i + 1 < ncols {
                    line.push('|');
                }
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers));
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// CSV with proper quoting.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

/// Format a float with `digits` decimals, trimming to a compact form.
pub fn fnum(v: f64, digits: usize) -> String {
    format!("{v:.digits$}")
}

/// Human-readable byte size (KiB/MiB/GiB).
pub fn human_bytes(b: u64) -> String {
    const KIB: f64 = 1024.0;
    let bf = b as f64;
    if bf >= KIB * KIB * KIB {
        format!("{:.2} GiB", bf / (KIB * KIB * KIB))
    } else if bf >= KIB * KIB {
        format!("{:.2} MiB", bf / (KIB * KIB))
    } else if bf >= KIB {
        format!("{:.2} KiB", bf / KIB)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "gflops"]);
        t.row_strs(&["laplace", "3.68"]);
        t.row_strs(&["bigstar", "10.65"]);
        let s = t.render();
        assert!(s.contains("laplace"));
        // Right-aligned numeric column: shorter value padded on the left.
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[2].ends_with("3.68 "));
    }

    #[test]
    fn csv_quoting() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["x,y", "he said \"hi\""]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only one"]);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
        assert_eq!(human_bytes(5 * 1024 * 1024 * 1024), "5.00 GiB");
    }

    #[test]
    fn title_in_render() {
        let t = Table::new(&["x"]).with_title("Table 3");
        assert!(t.render().starts_with("== Table 3 =="));
        assert_eq!(t.title(), Some("Table 3"));
    }

    #[test]
    fn context_pairs_are_kept_in_order() {
        let t = Table::new(&["x"])
            .with_context("arch", "KNL ddr")
            .with_context("input", "laplace");
        assert_eq!(
            t.context(),
            &[
                ("arch".to_string(), "KNL ddr".to_string()),
                ("input".to_string(), "laplace".to_string())
            ]
        );
    }

    #[test]
    fn json_rows_keep_numbers_numeric() {
        let mut t = Table::new(&["name", "seconds", "gain"]);
        t.row_strs(&["laplace", "0.125", "2.00x"]);
        assert_eq!(
            t.to_json().render(),
            r#"[{"name":"laplace","seconds":0.125,"gain":"2.00x"}]"#
        );
        assert_eq!(t.headers().len(), 3);
        assert_eq!(t.rows().len(), 1);
    }
}
