//! A small scoped thread pool (no `rayon`/`tokio` offline). Supports
//! parallel-for over index ranges with static chunking — the same
//! row-partitioning model KKMEM uses on KNL — plus a persistent pool for
//! the coordinator's executor.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// Run `f(chunk_start, chunk_end, thread_idx)` over `[0, n)` split into
/// `threads` contiguous chunks, each on its own OS thread (scoped).
pub fn parallel_for_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1);
    if threads == 1 || n == 1 {
        f(0, n, 0);
        return;
    }
    let chunk = n.div_ceil(threads);
    thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo, hi, t));
        }
    });
}

/// Dynamic (work-stealing-ish) parallel for: threads grab blocks of
/// `grain` indices from a shared atomic counter. Better load balance for
/// skewed rows (e.g. power-law graphs in triangle counting).
pub fn parallel_for_dynamic<F>(n: usize, threads: usize, grain: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let threads = threads.max(1);
    let grain = grain.max(1);
    if threads == 1 || n <= grain {
        f(0, n, 0);
        return;
    }
    let next = AtomicUsize::new(0);
    thread::scope(|s| {
        for t in 0..threads {
            let f = &f;
            let next = &next;
            s.spawn(move || loop {
                let lo = next.fetch_add(grain, Ordering::Relaxed);
                if lo >= n {
                    break;
                }
                let hi = (lo + grain).min(n);
                f(lo, hi, t);
            });
        }
    });
}

/// Map over items in parallel, preserving order of results.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        return items.into_iter().map(f).collect();
    }
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let work: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let queue = Mutex::new(work);
    let slots_ptr = Mutex::new(&mut slots);
    thread::scope(|s| {
        for _ in 0..threads {
            let f = &f;
            let queue = &queue;
            let slots_ptr = &slots_ptr;
            s.spawn(move || loop {
                let item = queue.lock().expect("queue poisoned").pop();
                match item {
                    Some((idx, x)) => {
                        let r = f(x);
                        let mut guard = slots_ptr.lock().expect("slots poisoned");
                        guard[idx] = Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    slots.into_iter().map(|o| o.expect("missing result")).collect()
}

/// Scheduling lane for the persistent worker pool: `High` jobs are
/// always dequeued before `Normal` ones (within a lane, FIFO). This is
/// the coordinator's priority lane — latency-sensitive submissions jump
/// the batch traffic without preempting a job already running.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Priority {
    High,
    #[default]
    Normal,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Transfer-profile tag the coordinator attaches to a submission:
/// `Some(true)` = copy-bound (predicted transfer time exceeds kernel
/// time), `Some(false)` = compute-bound, `None` = unknown (unpriced).
pub type CopyBound = Option<bool>;

/// Per-lane queue depths, with the total in-flight count — the
/// observable the metrics snapshot breaks out so lane starvation is
/// visible (a deep Normal lane behind an empty High lane).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QueueDepth {
    /// Jobs submitted but not finished (queued in either lane + running).
    pub pending: usize,
    /// Jobs waiting in the High lane.
    pub high: usize,
    /// Jobs waiting in the Normal lane.
    pub normal: usize,
}

struct Queued {
    run: Job,
    copy_bound: CopyBound,
}

/// The two-lane queue workers pop from: high lane drains first.
#[derive(Default)]
struct Lanes {
    high: VecDeque<Queued>,
    normal: VecDeque<Queued>,
    shutdown: bool,
    /// Tagged jobs currently executing, by profile — what the
    /// co-scheduler balances against.
    running_copy: usize,
    running_compute: usize,
}

/// Pop the next Normal-lane job. With co-scheduling on, when the
/// running mix is imbalanced the queue is scanned for the first job of
/// the complementary profile — one job's kernel time then hides
/// another's transfer time on the shared link (the §3 overlap-stream
/// discipline lifted from intra-job to inter-job). Untagged jobs are
/// never reordered around for; FIFO order is the fallback everywhere.
fn pick_normal(lanes: &mut Lanes, co_schedule: bool, hits: &AtomicU64) -> Option<Queued> {
    if co_schedule {
        let want = if lanes.running_copy > lanes.running_compute {
            Some(false) // link is loaded: prefer a compute-bound job
        } else if lanes.running_compute > lanes.running_copy {
            Some(true) // link is idle under kernels: prefer a copy-bound job
        } else {
            None
        };
        if let Some(w) = want {
            if let Some(idx) = lanes.normal.iter().position(|q| q.copy_bound == Some(w)) {
                if idx > 0 {
                    // Only an actual reorder counts as a co-schedule hit.
                    hits.fetch_add(1, Ordering::SeqCst);
                }
                return lanes.normal.remove(idx);
            }
        }
    }
    lanes.normal.pop_front()
}

/// A persistent worker pool executing boxed jobs from a two-lane
/// priority queue — backs the coordinator's executor.
pub struct WorkerPool {
    shared: Arc<(Mutex<Lanes>, Condvar)>,
    handles: Vec<thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
    co_schedule_hits: Arc<AtomicU64>,
}

impl WorkerPool {
    /// Co-scheduling pool: Normal-lane jobs may be reordered to pair
    /// copy-bound work with compute-bound work (see [`pick_normal`]).
    pub fn new(workers: usize) -> Self {
        Self::with_co_scheduling(workers, true)
    }

    /// Strict two-lane FIFO pool (the pre-contention scheduler) — the
    /// baseline the `contention` bench compares against.
    pub fn fifo(workers: usize) -> Self {
        Self::with_co_scheduling(workers, false)
    }

    fn with_co_scheduling(workers: usize, co_schedule: bool) -> Self {
        let workers = workers.max(1);
        let shared: Arc<(Mutex<Lanes>, Condvar)> =
            Arc::new((Mutex::new(Lanes::default()), Condvar::new()));
        let queued = Arc::new(AtomicUsize::new(0));
        let co_schedule_hits = Arc::new(AtomicU64::new(0));
        let handles = (0..workers)
            .map(|_| {
                let shared = Arc::clone(&shared);
                let queued = Arc::clone(&queued);
                let hits = Arc::clone(&co_schedule_hits);
                thread::spawn(move || loop {
                    let job = {
                        let (lock, cvar) = &*shared;
                        let mut lanes = lock.lock().expect("lanes poisoned");
                        loop {
                            // Drain remaining jobs even after shutdown so
                            // dropping the pool keeps the old
                            // finish-what-was-queued semantics.
                            let next = match lanes.high.pop_front() {
                                Some(j) => Some(j),
                                None => pick_normal(&mut lanes, co_schedule, &hits),
                            };
                            if let Some(j) = next {
                                match j.copy_bound {
                                    Some(true) => lanes.running_copy += 1,
                                    Some(false) => lanes.running_compute += 1,
                                    None => {}
                                }
                                break Some(j);
                            }
                            if lanes.shutdown {
                                break None;
                            }
                            lanes = cvar.wait(lanes).expect("lanes poisoned");
                        }
                    };
                    match job {
                        Some(job) => {
                            let tag = job.copy_bound;
                            (job.run)();
                            if tag.is_some() {
                                let (lock, _) = &*shared;
                                let mut lanes = lock.lock().expect("lanes poisoned");
                                match tag {
                                    Some(true) => lanes.running_copy -= 1,
                                    Some(false) => lanes.running_compute -= 1,
                                    None => {}
                                }
                            }
                            queued.fetch_sub(1, Ordering::SeqCst);
                        }
                        None => break,
                    }
                })
            })
            .collect();
        Self { shared, handles, queued, co_schedule_hits }
    }

    /// Number of jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::SeqCst)
    }

    /// Per-lane queue depths plus the total in-flight count.
    pub fn queue_depth(&self) -> QueueDepth {
        let (lock, _) = &*self.shared;
        let lanes = lock.lock().expect("lanes poisoned");
        QueueDepth {
            pending: self.pending(),
            high: lanes.high.len(),
            normal: lanes.normal.len(),
        }
    }

    /// Times the co-scheduler reordered the Normal lane to pair a
    /// copy-bound job with a compute-bound one.
    pub fn co_schedule_hits(&self) -> u64 {
        self.co_schedule_hits.load(Ordering::SeqCst)
    }

    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.submit_with(Priority::Normal, job);
    }

    /// Submit into a specific lane; `High` jobs run before queued
    /// `Normal` jobs.
    pub fn submit_with(&self, priority: Priority, job: impl FnOnce() + Send + 'static) {
        self.submit_tagged(priority, None, job);
    }

    /// Submit with a transfer-profile tag; the co-scheduler uses tags to
    /// pair copy-bound jobs with compute-bound ones in the Normal lane.
    pub fn submit_tagged(
        &self,
        priority: Priority,
        copy_bound: CopyBound,
        job: impl FnOnce() + Send + 'static,
    ) {
        self.queued.fetch_add(1, Ordering::SeqCst);
        let (lock, cvar) = &*self.shared;
        let mut lanes = lock.lock().expect("lanes poisoned");
        assert!(!lanes.shutdown, "pool already shut down");
        let q = Queued { run: Box::new(job), copy_bound };
        match priority {
            Priority::High => lanes.high.push_back(q),
            Priority::Normal => lanes.normal.push_back(q),
        }
        drop(lanes);
        cvar.notify_one();
    }

    /// Block until all submitted jobs have completed.
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            thread::yield_now();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let (lock, cvar) = &*self.shared;
            lock.lock().expect("lanes poisoned").shutdown = true;
            cvar.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunked_covers_all_indices_once() {
        let n = 1003;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_chunks(n, 7, |lo, hi, _| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn dynamic_covers_all_indices_once() {
        let n = 517;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_dynamic(n, 5, 16, |lo, hi, _| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let out = parallel_map((0..100).collect::<Vec<_>>(), 8, |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_single_thread_path() {
        let out = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn worker_pool_runs_all_jobs() {
        let pool = WorkerPool::new(4);
        let sum = Arc::new(AtomicU64::new(0));
        for i in 0..100u64 {
            let sum = Arc::clone(&sum);
            pool.submit(move || {
                sum.fetch_add(i, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(sum.load(Ordering::SeqCst), 4950);
    }

    #[test]
    fn high_lane_jumps_queued_normal_jobs() {
        // One worker pinned on a gate job; while it blocks, a Normal then
        // a High job are queued. The High job must run first.
        let pool = WorkerPool::new(1);
        let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
        pool.submit(move || {
            gate_rx.recv().expect("gate");
        });
        let order = Arc::new(Mutex::new(Vec::new()));
        let (o1, o2) = (Arc::clone(&order), Arc::clone(&order));
        pool.submit_with(Priority::Normal, move || {
            o1.lock().expect("order").push("normal");
        });
        pool.submit_with(Priority::High, move || {
            o2.lock().expect("order").push("high");
        });
        gate_tx.send(()).expect("open gate");
        pool.wait_idle();
        assert_eq!(*order.lock().expect("order"), vec!["high", "normal"]);
    }

    #[test]
    fn co_scheduler_pairs_compute_with_running_copy_job() {
        // Worker 1 holds a copy-bound gate job; worker 2 holds an
        // untagged gate. Queue a copy-bound then a compute-bound job,
        // release worker 2 only: with a copy-bound job running, the
        // co-scheduler must skip the queued copy job and run the
        // compute job first (one reorder = one hit).
        let pool = WorkerPool::new(2);
        let (copy_gate_tx, copy_gate_rx) = std::sync::mpsc::channel::<()>();
        let (free_gate_tx, free_gate_rx) = std::sync::mpsc::channel::<()>();
        pool.submit_tagged(Priority::Normal, Some(true), move || {
            copy_gate_rx.recv().expect("copy gate");
        });
        pool.submit_tagged(Priority::Normal, None, move || {
            free_gate_rx.recv().expect("free gate");
        });
        let order = Arc::new(Mutex::new(Vec::new()));
        let (o1, o2) = (Arc::clone(&order), Arc::clone(&order));
        pool.submit_tagged(Priority::Normal, Some(true), move || {
            o1.lock().expect("order").push("copy");
        });
        pool.submit_tagged(Priority::Normal, Some(false), move || {
            o2.lock().expect("order").push("compute");
        });
        free_gate_tx.send(()).expect("open free gate");
        // The freed worker drains both queued jobs while the copy gate
        // still holds the other worker.
        while pool.pending() > 1 {
            thread::yield_now();
        }
        copy_gate_tx.send(()).expect("open copy gate");
        pool.wait_idle();
        assert_eq!(*order.lock().expect("order"), vec!["compute", "copy"]);
        assert_eq!(pool.co_schedule_hits(), 1);
    }

    #[test]
    fn fifo_pool_never_reorders() {
        let pool = WorkerPool::fifo(1);
        let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
        pool.submit_tagged(Priority::Normal, Some(true), move || {
            gate_rx.recv().expect("gate");
        });
        let order = Arc::new(Mutex::new(Vec::new()));
        let (o1, o2) = (Arc::clone(&order), Arc::clone(&order));
        pool.submit_tagged(Priority::Normal, Some(true), move || {
            o1.lock().expect("order").push("copy");
        });
        pool.submit_tagged(Priority::Normal, Some(false), move || {
            o2.lock().expect("order").push("compute");
        });
        gate_tx.send(()).expect("open gate");
        pool.wait_idle();
        assert_eq!(*order.lock().expect("order"), vec!["copy", "compute"]);
        assert_eq!(pool.co_schedule_hits(), 0);
    }

    #[test]
    fn queue_depth_breaks_out_lanes() {
        let pool = WorkerPool::new(1);
        let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
        pool.submit(move || {
            gate_rx.recv().expect("gate");
        });
        // Wait until the gate job is actually running (off the queue).
        while pool.queue_depth().normal > 0 {
            thread::yield_now();
        }
        pool.submit_with(Priority::Normal, || {});
        pool.submit_with(Priority::High, || {});
        pool.submit_with(Priority::High, || {});
        let d = pool.queue_depth();
        assert_eq!((d.pending, d.high, d.normal), (4, 2, 1));
        gate_tx.send(()).expect("open gate");
        pool.wait_idle();
        assert_eq!(pool.queue_depth(), QueueDepth::default());
    }

    #[test]
    fn worker_pool_drop_joins() {
        let pool = WorkerPool::new(2);
        let flag = Arc::new(AtomicUsize::new(0));
        let f2 = Arc::clone(&flag);
        pool.submit(move || {
            f2.fetch_add(1, Ordering::SeqCst);
        });
        drop(pool);
        assert_eq!(flag.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn zero_items_is_fine() {
        parallel_for_chunks(0, 4, |_, _, _| panic!("no work expected"));
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }
}
