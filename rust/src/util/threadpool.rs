//! A small scoped thread pool (no `rayon`/`tokio` offline). Supports
//! parallel-for over index ranges with static chunking — the same
//! row-partitioning model KKMEM uses on KNL — plus a persistent pool for
//! the coordinator's executor.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// Run `f(chunk_start, chunk_end, thread_idx)` over `[0, n)` split into
/// `threads` contiguous chunks, each on its own OS thread (scoped).
pub fn parallel_for_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1);
    if threads == 1 || n == 1 {
        f(0, n, 0);
        return;
    }
    let chunk = n.div_ceil(threads);
    thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo, hi, t));
        }
    });
}

/// Dynamic (work-stealing-ish) parallel for: threads grab blocks of
/// `grain` indices from a shared atomic counter. Better load balance for
/// skewed rows (e.g. power-law graphs in triangle counting).
pub fn parallel_for_dynamic<F>(n: usize, threads: usize, grain: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let threads = threads.max(1);
    let grain = grain.max(1);
    if threads == 1 || n <= grain {
        f(0, n, 0);
        return;
    }
    let next = AtomicUsize::new(0);
    thread::scope(|s| {
        for t in 0..threads {
            let f = &f;
            let next = &next;
            s.spawn(move || loop {
                let lo = next.fetch_add(grain, Ordering::Relaxed);
                if lo >= n {
                    break;
                }
                let hi = (lo + grain).min(n);
                f(lo, hi, t);
            });
        }
    });
}

/// Map over items in parallel, preserving order of results.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        return items.into_iter().map(f).collect();
    }
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let work: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let queue = Mutex::new(work);
    let slots_ptr = Mutex::new(&mut slots);
    thread::scope(|s| {
        for _ in 0..threads {
            let f = &f;
            let queue = &queue;
            let slots_ptr = &slots_ptr;
            s.spawn(move || loop {
                let item = queue.lock().expect("queue poisoned").pop();
                match item {
                    Some((idx, x)) => {
                        let r = f(x);
                        let mut guard = slots_ptr.lock().expect("slots poisoned");
                        guard[idx] = Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    slots.into_iter().map(|o| o.expect("missing result")).collect()
}

/// Scheduling lane for the persistent worker pool: `High` jobs are
/// always dequeued before `Normal` ones (within a lane, FIFO). This is
/// the coordinator's priority lane — latency-sensitive submissions jump
/// the batch traffic without preempting a job already running.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Priority {
    High,
    #[default]
    Normal,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// The two-lane queue workers pop from: high lane drains first.
#[derive(Default)]
struct Lanes {
    high: VecDeque<Job>,
    normal: VecDeque<Job>,
    shutdown: bool,
}

/// A persistent worker pool executing boxed jobs from a two-lane
/// priority queue — backs the coordinator's executor.
pub struct WorkerPool {
    shared: Arc<(Mutex<Lanes>, Condvar)>,
    handles: Vec<thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl WorkerPool {
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let shared: Arc<(Mutex<Lanes>, Condvar)> =
            Arc::new((Mutex::new(Lanes::default()), Condvar::new()));
        let queued = Arc::new(AtomicUsize::new(0));
        let handles = (0..workers)
            .map(|_| {
                let shared = Arc::clone(&shared);
                let queued = Arc::clone(&queued);
                thread::spawn(move || loop {
                    let job = {
                        let (lock, cvar) = &*shared;
                        let mut lanes = lock.lock().expect("lanes poisoned");
                        loop {
                            // Drain remaining jobs even after shutdown so
                            // dropping the pool keeps the old
                            // finish-what-was-queued semantics.
                            let next = match lanes.high.pop_front() {
                                Some(j) => Some(j),
                                None => lanes.normal.pop_front(),
                            };
                            if let Some(j) = next {
                                break Some(j);
                            }
                            if lanes.shutdown {
                                break None;
                            }
                            lanes = cvar.wait(lanes).expect("lanes poisoned");
                        }
                    };
                    match job {
                        Some(job) => {
                            job();
                            queued.fetch_sub(1, Ordering::SeqCst);
                        }
                        None => break,
                    }
                })
            })
            .collect();
        Self { shared, handles, queued }
    }

    /// Number of jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::SeqCst)
    }

    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.submit_with(Priority::Normal, job);
    }

    /// Submit into a specific lane; `High` jobs run before queued
    /// `Normal` jobs.
    pub fn submit_with(&self, priority: Priority, job: impl FnOnce() + Send + 'static) {
        self.queued.fetch_add(1, Ordering::SeqCst);
        let (lock, cvar) = &*self.shared;
        let mut lanes = lock.lock().expect("lanes poisoned");
        assert!(!lanes.shutdown, "pool already shut down");
        match priority {
            Priority::High => lanes.high.push_back(Box::new(job)),
            Priority::Normal => lanes.normal.push_back(Box::new(job)),
        }
        drop(lanes);
        cvar.notify_one();
    }

    /// Block until all submitted jobs have completed.
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            thread::yield_now();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let (lock, cvar) = &*self.shared;
            lock.lock().expect("lanes poisoned").shutdown = true;
            cvar.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunked_covers_all_indices_once() {
        let n = 1003;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_chunks(n, 7, |lo, hi, _| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn dynamic_covers_all_indices_once() {
        let n = 517;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_dynamic(n, 5, 16, |lo, hi, _| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let out = parallel_map((0..100).collect::<Vec<_>>(), 8, |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_single_thread_path() {
        let out = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn worker_pool_runs_all_jobs() {
        let pool = WorkerPool::new(4);
        let sum = Arc::new(AtomicU64::new(0));
        for i in 0..100u64 {
            let sum = Arc::clone(&sum);
            pool.submit(move || {
                sum.fetch_add(i, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(sum.load(Ordering::SeqCst), 4950);
    }

    #[test]
    fn high_lane_jumps_queued_normal_jobs() {
        // One worker pinned on a gate job; while it blocks, a Normal then
        // a High job are queued. The High job must run first.
        let pool = WorkerPool::new(1);
        let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
        pool.submit(move || {
            gate_rx.recv().expect("gate");
        });
        let order = Arc::new(Mutex::new(Vec::new()));
        let (o1, o2) = (Arc::clone(&order), Arc::clone(&order));
        pool.submit_with(Priority::Normal, move || {
            o1.lock().expect("order").push("normal");
        });
        pool.submit_with(Priority::High, move || {
            o2.lock().expect("order").push("high");
        });
        gate_tx.send(()).expect("open gate");
        pool.wait_idle();
        assert_eq!(*order.lock().expect("order"), vec!["high", "normal"]);
    }

    #[test]
    fn worker_pool_drop_joins() {
        let pool = WorkerPool::new(2);
        let flag = Arc::new(AtomicUsize::new(0));
        let f2 = Arc::clone(&flag);
        pool.submit(move || {
            f2.fetch_add(1, Ordering::SeqCst);
        });
        drop(pool);
        assert_eq!(flag.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn zero_items_is_fine() {
        parallel_for_chunks(0, 4, |_, _, _| panic!("no work expected"));
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }
}
