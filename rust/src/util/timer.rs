//! Monotonic wall-clock timing helpers for the native (non-simulated)
//! performance benches.

use std::time::{Duration, Instant};

/// A simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Run `f` once and return (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_secs())
}

/// Benchmark `f`: `warmup` unmeasured runs then `reps` measured runs,
/// returning per-run seconds. The closure receives the rep index so callers
/// can rotate inputs and defeat value caching.
pub fn bench_runs(warmup: usize, reps: usize, mut f: impl FnMut(usize)) -> Vec<f64> {
    for i in 0..warmup {
        f(i);
    }
    (0..reps)
        .map(|i| {
            let t = Timer::start();
            f(i);
            t.elapsed_secs()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_result() {
        let (v, secs) = time_it(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn bench_runs_counts() {
        let mut calls = 0usize;
        let samples = bench_runs(2, 5, |_| calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(samples.len(), 5);
    }
}
