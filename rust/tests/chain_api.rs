//! Chain-execution integration suite: `Session::execute_chain` /
//! `chain_with` against naive pairwise `Session::execute`-style hops —
//! bit-identical products for stencil, power-law, and multigrid R·A·P
//! inputs, typed cancellation/deadline errors at hop boundaries, and the
//! headline acceptance scenario where the left-to-right intermediate
//! exceeds the GPU fast pool and the chain-planned run beats pairwise
//! execution with eviction between hops.

use mlmem_spgemm::coordinator::{ChainAssoc, Session, SubmitOptions};
use mlmem_spgemm::error::JobControl;
use mlmem_spgemm::gen::multigrid::MgProblem;
use mlmem_spgemm::gen::scale::ScaleFactor;
use mlmem_spgemm::gen::stencil::{Domain, Grid};
use mlmem_spgemm::memory::arch::{knl, p100, Arch, GpuMode, KnlMode};
use mlmem_spgemm::memory::FAST;
use mlmem_spgemm::prelude::*;
use mlmem_spgemm::sparse::ops::spgemm_reference;
use mlmem_spgemm::MatrixHandle;
use std::sync::Arc;
use std::time::Duration;

fn knl_arch() -> Arc<Arch> {
    Arc::new(knl(KnlMode::Ddr, 64, ScaleFactor::default()))
}

/// Bitwise comparison up to row-entry ordering (`approx_eq` with zero
/// tolerance cancels entries exactly).
fn bit_identical(a: &Csr, b: &Csr) -> bool {
    a.approx_eq(b, 0.0)
}

/// Naive pairwise baseline: independent jobs in the given association
/// order, every intermediate materialized and consumed cold ("evicted"
/// between hops). Returns (total simulated seconds, product).
fn pairwise_in_order(
    session: &Session,
    h: &[MatrixHandle; 3],
    assoc: ChainAssoc,
) -> (f64, Csr) {
    let run = |a: MatrixHandle, b: MatrixHandle| {
        let r = session
            .spgemm_with(a, b, SubmitOptions { keep_product: true, ..Default::default() })
            .expect("admitted")
            .wait()
            .expect("hop succeeds");
        let c = r.c.expect("keep_product attaches C");
        (r.report.seconds, c)
    };
    match assoc {
        ChainAssoc::LeftFold => {
            let (s1, c1) = run(h[0], h[1]);
            let hc = session.register(Arc::new(c1));
            let (s2, c2) = run(hc, h[2]);
            (s1 + s2, c2)
        }
        ChainAssoc::RightFold => {
            let (s1, c1) = run(h[1], h[2]);
            let hc = session.register(Arc::new(c1));
            let (s2, c2) = run(h[0], hc);
            (s1 + s2, c2)
        }
    }
}

/// Run a 3-chain and check it against (a) the plain reference product
/// and (b) a pairwise replay in the chain's chosen association order,
/// which must be bit-identical.
fn check_chain_bit_identical(session: &Session, mats: [Arc<Csr>; 3]) {
    let reference = spgemm_reference(&spgemm_reference(&mats[0], &mats[1]), &mats[2]);
    let handles = [
        session.register(Arc::clone(&mats[0])),
        session.register(Arc::clone(&mats[1])),
        session.register(Arc::clone(&mats[2])),
    ];
    let result = session.execute_chain(&handles).expect("chain succeeds");
    let chain = result.chain.as_ref().expect("chain summary present");
    assert_eq!(chain.hops.len(), 2);
    assert_eq!(chain.order_scores.len(), 2, "both association orders scored");
    let c = result.c.as_ref().expect("execute_chain keeps the product");
    assert_eq!((c.nrows, c.ncols), (reference.nrows, reference.ncols));
    // Chains never change the math beyond association order: per-column
    // sums fold left in k order in every driver, so the chain product is
    // bit-identical to pairwise hops replayed in the same order.
    let (_, pairwise_c) = pairwise_in_order(session, &handles, chain.assoc);
    assert!(bit_identical(c, &pairwise_c), "chain != pairwise replay (bitwise)");
    // And numerically the reference product up to FP association error.
    assert!(c.approx_eq(&reference, 1e-9), "chain far from reference");
}

#[test]
fn chain_bit_identical_stencil() {
    let session = Session::builder(knl_arch()).workers(1).build();
    let a = Arc::new(mlmem_spgemm::gen::stencil::laplace3d(Grid::new(6, 6, 6)));
    check_chain_bit_identical(&session, [Arc::clone(&a), Arc::clone(&a), a]);
}

#[test]
fn chain_bit_identical_power_law() {
    let session = Session::builder(knl_arch()).workers(1).build();
    let g = Arc::new(mlmem_spgemm::gen::graphs::graph500(7, 8, 11));
    check_chain_bit_identical(&session, [Arc::clone(&g), Arc::clone(&g), g]);
}

#[test]
fn chain_bit_identical_multigrid_rap() {
    let session = Session::builder(knl_arch()).workers(1).build();
    let p = MgProblem::build(Domain::Laplace3D, Grid::new(6, 6, 6), 2);
    check_chain_bit_identical(
        &session,
        [Arc::new(p.r), Arc::new(p.a), Arc::new(p.p)],
    );
}

#[test]
fn two_matrix_chain_degenerates_to_single_hop() {
    let session = Session::builder(knl_arch()).workers(1).build();
    let a = session.register(Arc::new(mlmem_spgemm::gen::rhs::random_csr(50, 40, 1, 5, 1)));
    let b = session.register(Arc::new(mlmem_spgemm::gen::rhs::random_csr(40, 60, 1, 5, 2)));
    let r = session.execute_chain(&[a, b]).expect("chain ok");
    let chain = r.chain.as_ref().expect("summary");
    assert_eq!(chain.hops.len(), 1);
    assert_eq!(chain.assoc, ChainAssoc::LeftFold);
    assert!(chain.order_scores.is_empty(), "nothing to score for n=2");
    let ma = session.operand(a).unwrap();
    let mb = session.operand(b).unwrap();
    assert!(r.c.unwrap().approx_eq(&spgemm_reference(&ma, &mb), 1e-12));
}

#[test]
fn four_matrix_chain_folds_left() {
    let session = Session::builder(knl_arch()).workers(1).build();
    let mats: Vec<Arc<Csr>> = (0..4)
        .map(|i| Arc::new(mlmem_spgemm::gen::rhs::random_csr(40, 40, 1, 4, 10 + i)))
        .collect();
    let handles: Vec<_> = mats.iter().map(|m| session.register(Arc::clone(m))).collect();
    let r = session.execute_chain(&handles).expect("chain ok");
    let chain = r.chain.as_ref().expect("summary");
    assert_eq!(chain.hops.len(), 3);
    assert_eq!(chain.assoc, ChainAssoc::LeftFold);
    let mut expect = spgemm_reference(&mats[0], &mats[1]);
    expect = spgemm_reference(&expect, &mats[2]);
    expect = spgemm_reference(&expect, &mats[3]);
    assert!(r.c.unwrap().approx_eq(&expect, 1e-9), "left fold replays the reference");
}

#[test]
fn chain_shape_mismatch_and_arity_are_typed() {
    let session = Session::builder(knl_arch()).build();
    let a = session.register(Arc::new(mlmem_spgemm::gen::rhs::random_csr(10, 7, 1, 3, 1)));
    let b = session.register(Arc::new(mlmem_spgemm::gen::rhs::random_csr(9, 5, 1, 3, 2)));
    assert!(matches!(
        session.execute_chain(&[a, b]),
        Err(MlmemError::ShapeMismatch { .. })
    ));
    assert!(matches!(session.execute_chain(&[a]), Err(MlmemError::Planner(_))));
    // Handles are session-scoped: one minted by a *different* session
    // with more registrations carries an id this session never issued.
    let other = Session::builder(knl_arch()).build();
    let mut foreign = other.register(Arc::new(mlmem_spgemm::gen::rhs::random_csr(7, 7, 1, 3, 3)));
    for seed in 4..6 {
        foreign = other.register(Arc::new(mlmem_spgemm::gen::rhs::random_csr(7, 7, 1, 3, seed)));
    }
    assert!(matches!(
        session.execute_chain(&[a, foreign]),
        Err(MlmemError::UnknownHandle(3))
    ));
}

#[test]
fn resident_intermediate_when_everything_fits_fast() {
    // Small multigrid triple product on KNL: every hop fits the fast
    // pool, so hop 1 runs flat-fast and leaves its product there — hop 2
    // must consume it resident (no promotion transfer).
    let session = Session::builder(knl_arch()).workers(1).build();
    let p = MgProblem::build(Domain::Laplace3D, Grid::new(8, 8, 8), 2);
    let hr = session.register(Arc::new(p.r));
    let ha = session.register(Arc::new(p.a));
    let hp = session.register(Arc::new(p.p));
    let r = session.execute_chain(&[hr, ha, hp]).expect("chain ok");
    let chain = r.chain.as_ref().expect("summary");
    assert_eq!(
        chain.hops[0].decision,
        mlmem_spgemm::coordinator::Decision::FlatFast,
        "premise: the first hop fits the fast pool"
    );
    assert!(
        chain.hops[1].residency.any(),
        "hop 2 must consume the fast-resident intermediate"
    );
    assert_eq!(chain.hops[1].promote_seconds, 0.0, "residency was free");
    assert_eq!(chain.promote_seconds(), 0.0);
    assert!(chain.any_resident_hop());
}

#[test]
fn chain_reuses_the_registry_pair_cache() {
    // A 3-chain touches two registered operand pairs; both symbolic
    // passes go through the session's pair cache, so a second identical
    // chain computes none (intermediates are uncacheable by nature and
    // are not counted by the registry).
    let session = Session::builder(knl_arch()).workers(1).build();
    let p = MgProblem::build(Domain::Laplace3D, Grid::new(6, 6, 6), 2);
    let hr = session.register(Arc::new(p.r));
    let ha = session.register(Arc::new(p.a));
    let hp = session.register(Arc::new(p.p));
    session.execute_chain(&[hr, ha, hp]).expect("chain ok");
    assert_eq!(session.symbolic_passes(), 2, "one pass per registered pair");
    session.execute_chain(&[hr, ha, hp]).expect("chain ok again");
    assert_eq!(session.symbolic_passes(), 2, "second chain hits the cache");
    // The fast-pool residency cache covers chain operands: every hop of
    // this tiny chain runs flat-fast, so all three operands were
    // captured and report as resident (DESIGN.md §9).
    assert!(session.residency(hr).is_some());
    assert!(session.residency(ha).is_some());
    assert!(session.residency(hp).is_some());
}

#[test]
fn chain_cancellation_and_deadline_at_hop_boundaries() {
    let session = Session::builder(knl_arch()).workers(1).build();
    let p = MgProblem::build(Domain::Laplace3D, Grid::new(8, 8, 8), 2);
    let hr = session.register(Arc::new(p.r));
    let ha = session.register(Arc::new(p.a));
    let hp = session.register(Arc::new(p.p));

    // Pre-cancelled control: observed at the first hop boundary.
    let control = JobControl::new();
    control.cancel();
    let h = session
        .chain_with(
            &[hr, ha, hp],
            SubmitOptions { control: Some(control), ..Default::default() },
        )
        .expect("admitted");
    assert!(matches!(h.wait(), Err(MlmemError::Cancelled)));

    // Already-expired deadline: typed DeadlineExceeded, not a failure.
    let h = session
        .chain_with(
            &[hr, ha, hp],
            SubmitOptions { deadline: Some(Duration::ZERO), ..Default::default() },
        )
        .expect("admitted");
    assert!(matches!(h.wait(), Err(MlmemError::DeadlineExceeded)));

    session.drain();
    let m = session.metrics();
    assert_eq!((m.cancelled, m.failed), (2, 0));

    // A short-but-nonzero deadline on a long chain expires mid-flight at
    // a hop or chunk boundary — still the typed error.
    let big = MgProblem::build(Domain::Laplace3D, Grid::new(20, 20, 20), 2);
    let hr = session.register(Arc::new(big.r));
    let ha = session.register(Arc::new(big.a));
    let hp = session.register(Arc::new(big.p));
    let h = session
        .chain_with(
            &[hr, ha, hp],
            SubmitOptions { deadline: Some(Duration::from_millis(1)), ..Default::default() },
        )
        .expect("admitted");
    assert!(matches!(h.wait(), Err(MlmemError::DeadlineExceeded)));
}

/// The acceptance scenario (ISSUE 4): a multigrid R·A·P instance on the
/// GPU (pinned host) profile whose **left-to-right intermediate R·A
/// exceeds the fast pool**. Naive pairwise execution is stuck
/// materializing and re-consuming that oversized intermediate across the
/// slow link; the chain planner predicts this, picks `R·(A·P)` whose
/// intermediate fits, and wins on simulated time with a bit-identical
/// coarse operator.
#[test]
fn chain_beats_pairwise_when_intermediate_exceeds_gpu_fast_pool() {
    let prob = MgProblem::build(Domain::Laplace3D, Grid::new(20, 20, 20), 2);
    let ra = spgemm_reference(&prob.r, &prob.a);
    let ap = spgemm_reference(&prob.a, &prob.p);
    let reference = spgemm_reference(&ra, &prob.p);
    let slack = 1u64 << 16;
    assert!(
        ap.size_bytes() + 2 * slack < ra.size_bytes(),
        "construction drifted: AP {} vs RA {}",
        ap.size_bytes(),
        ra.size_bytes()
    );
    // Size the fast pool between the two intermediates: A·P (plus the
    // planner's slack) fits and can stay resident; R·A does not.
    let usable = (ap.size_bytes() + slack + ra.size_bytes()) / 2;
    let mut arch = p100(GpuMode::Pinned, ScaleFactor::default());
    let headroom = arch.spec.pools[FAST.0].alloc_headroom;
    arch.spec.pools[FAST.0].capacity = (usable as f64 / headroom) as u64 + 1;
    let usable = arch.spec.pools[FAST.0].usable();
    assert!(ra.size_bytes() > usable, "premise: R·A exceeds the fast pool");
    assert!(ap.size_bytes() + slack <= usable, "premise: A·P fits the fast pool");

    let arch = Arc::new(arch);
    let r_mat = Arc::new(prob.r);
    let a_mat = Arc::new(prob.a);
    let p_mat = Arc::new(prob.p);
    let session = Session::builder(Arc::clone(&arch)).workers(1).build();
    let hr = session.register(Arc::clone(&r_mat));
    let ha = session.register(Arc::clone(&a_mat));
    let hp = session.register(Arc::clone(&p_mat));
    let handles = [hr, ha, hp];

    let result = session.execute_chain(&handles).expect("chain succeeds");
    let chain = result.chain.as_ref().expect("summary");
    assert_eq!(
        chain.assoc,
        ChainAssoc::RightFold,
        "planner must route around the oversized R·A intermediate \
         (order scores: {:?})",
        chain.order_scores
    );

    // Naive pairwise, left-to-right, eviction between hops — on a
    // cache-disabled session, so the chain's fast-pool captures cannot
    // quietly subsidize the baseline it is judged against.
    let baseline = Session::builder(Arc::clone(&arch))
        .workers(1)
        .operand_cache(false)
        .build();
    let bh = [
        baseline.register(Arc::clone(&r_mat)),
        baseline.register(Arc::clone(&a_mat)),
        baseline.register(Arc::clone(&p_mat)),
    ];
    let (pairwise_seconds, _) = pairwise_in_order(&baseline, &bh, ChainAssoc::LeftFold);
    assert!(
        result.report.seconds < pairwise_seconds,
        "chain {} !< pairwise {} (hops: {:?})",
        result.report.seconds,
        pairwise_seconds,
        chain.hops.iter().map(|h| h.decision.name()).collect::<Vec<_>>()
    );

    // Bit-identical coarse operator: the chain adds no numerical
    // deviation over pairwise hops in its chosen order...
    let (_, replay_c) = pairwise_in_order(&session, &handles, chain.assoc);
    let c = result.c.as_ref().expect("product kept");
    assert!(bit_identical(c, &replay_c), "coarse operator must be bit-identical");
    // ...and matches the reference triple product numerically.
    assert!(c.approx_eq(&reference, 1e-9));

    // The chain's prediction machinery stayed observable.
    assert!(result.predicted.is_some());
    for hop in &chain.hops {
        assert!(!hop.candidates.is_empty(), "Auto hops record candidate tables");
        assert!(hop.report.seconds > 0.0);
    }
}
