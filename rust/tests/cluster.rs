//! Cluster-layer integration suite: the capacity win (a product too big
//! for one node completes on four nodes, bit-identical to the in-memory
//! reference), bit-identical merges across node counts and input
//! families, and the block-row partition invariants (DESIGN.md §12).

use mlmem_spgemm::cluster::{self, ClusterSpec, Fabric, ShardPlan};
use mlmem_spgemm::coordinator::{
    execute as planner_execute, Job, JobKind, PlannerOptions, Policy,
};
use mlmem_spgemm::gen::graphs::graph500;
use mlmem_spgemm::gen::rhs::uniform_degree;
use mlmem_spgemm::gen::scale::ScaleFactor;
use mlmem_spgemm::gen::stencil::{laplace3d, Grid};
use mlmem_spgemm::memory::arch::{knl, Arch, KnlMode};
use mlmem_spgemm::sparse::ops::{spgemm_flops, spgemm_reference};
use mlmem_spgemm::sparse::Csr;
use mlmem_spgemm::util::proptest::{check, Gen};
use std::sync::Arc;

/// Sort each row by column. Engines agree on values bit-for-bit but not
/// on per-row entry order (hash-family engines emit rows unsorted), so
/// comparisons canonicalize first.
fn canonical(c: &Csr) -> Csr {
    let mut rowmap = vec![0usize];
    let mut entries = Vec::with_capacity(c.nnz());
    let mut values = Vec::with_capacity(c.nnz());
    for i in 0..c.nrows {
        let (cols, vals) = c.row(i);
        let mut row: Vec<(u32, f64)> =
            cols.iter().copied().zip(vals.iter().copied()).collect();
        row.sort_by_key(|&(col, _)| col);
        for (col, v) in row {
            entries.push(col);
            values.push(v);
        }
        rowmap.push(entries.len());
    }
    Csr::new(c.nrows, c.ncols, rowmap, entries, values)
}

fn assert_bit_identical(got: &Csr, want: &Csr, ctx: &str) {
    assert_eq!(got.rowmap, want.rowmap, "{ctx}: rowmap");
    assert_eq!(got.entries, want.entries, "{ctx}: entries");
    let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
    assert_eq!(bits(&got.values), bits(&want.values), "{ctx}: values");
}

fn cluster_product(a: &Arc<Csr>, b: &Arc<Csr>, arch: &Arc<Arch>, nodes: usize) -> Csr {
    let spec = ClusterSpec::new(nodes);
    let fabric = Fabric::new(spec.fabric);
    cluster::execute(a, b, arch, &spec, &fabric, &PlannerOptions::default())
        .unwrap_or_else(|e| panic!("nodes={nodes}: {e}"))
        .c
}

/// The headline capacity win: shrink the machine until C (~1.57 MB)
/// exceeds one node's slow pool (~964 KB usable). The single-node Auto
/// planner must refuse — allocation is enforced and there is no fallback
/// — while four nodes' ~530 KB shards fit, and the merged product is
/// bit-identical to the in-memory reference.
#[test]
fn over_capacity_product_completes_on_four_nodes() {
    let arch = Arc::new(knl(KnlMode::Ddr, 64, ScaleFactor::new(96 * 1024)));
    let a = Arc::new(uniform_degree(4096, 512, 8, 1));
    let b = Arc::new(uniform_degree(512, 512, 4, 2));

    let mut job = Job::new(
        1,
        JobKind::Spgemm { a: Arc::clone(&a), b: Arc::clone(&b) },
        Arc::clone(&arch),
        Policy::Auto,
    );
    job.keep_product = true;
    let single = planner_execute(&job, &PlannerOptions::default());
    assert!(single.is_err(), "single node unexpectedly fit the product");

    let spec = ClusterSpec::new(4);
    let fabric = Fabric::new(spec.fabric);
    let out = cluster::execute(&a, &b, &arch, &spec, &fabric, &PlannerOptions::default())
        .expect("4-node cluster completes the over-capacity product");
    assert_bit_identical(
        &canonical(&out.c),
        &canonical(&spgemm_reference(&a, &b)),
        "over-capacity 4-node",
    );
    assert!(out.scatter_seconds > 0.0, "remote shards paid no scatter");
    assert!(fabric.stats().bytes > 0, "fabric moved no bytes");
}

/// Random conformable pairs through every node count: the merged C is
/// bit-identical to the reference regardless of where the row split falls.
#[test]
fn sharded_merge_is_bit_identical_across_node_counts() {
    check("cluster merge matches reference bitwise", 24, |g: &mut Gen| {
        let arch = Arc::new(knl(KnlMode::Ddr, 64, ScaleFactor::new(1 << 10)));
        let (a, b) = g.csr_pair(96, 8);
        let a = Arc::new(a);
        let b = Arc::new(b);
        let want = canonical(&spgemm_reference(&a, &b));
        let nodes = g.usize(1, 8);
        let got = canonical(&cluster_product(&a, &b, &arch, nodes));
        assert_bit_identical(&got, &want, &format!("nodes={nodes}"));
    });
}

/// The paper's structured input families — a power-law Graph500 square
/// and a 3D Laplace stencil square — shard cleanly at every node count.
#[test]
fn powerlaw_and_stencil_products_shard_cleanly() {
    let arch = Arc::new(knl(KnlMode::Ddr, 64, ScaleFactor::new(1 << 10)));
    let g500 = Arc::new(graph500(7, 8, 7));
    let lap = Arc::new(laplace3d(Grid::new(8, 8, 8)));
    for (name, m) in [("powerlaw-g500", &g500), ("laplace3d", &lap)] {
        let want = canonical(&spgemm_reference(m, m));
        for nodes in [1usize, 2, 3, 5, 8] {
            let got = canonical(&cluster_product(m, m, &arch, nodes));
            assert_bit_identical(&got, &want, &format!("{name} nodes={nodes}"));
        }
    }
}

/// Block-row partition invariants: ranges are contiguous and cover
/// `[0, m)` exactly, every row has exactly one owner, and the per-shard
/// symbolic sizes sum to the global symbolic count.
#[test]
fn partition_invariants_hold_for_random_inputs() {
    check("block-row partition invariants", 64, |g: &mut Gen| {
        let (a, b) = g.csr_pair(128, 6);
        let nodes = g.usize(1, 9);
        let plan = ShardPlan::build(&a, &b, nodes);
        let p = &plan.partition;
        assert_eq!(p.nodes(), nodes);
        let mut next = 0usize;
        for &(lo, hi) in &p.ranges {
            assert_eq!(lo, next, "ranges must be contiguous");
            assert!(hi >= lo);
            next = hi;
        }
        assert_eq!(next, a.nrows, "ranges must cover every row");
        for row in 0..a.nrows {
            let owner = p.owner_of(row).expect("every row is owned");
            let (lo, hi) = p.ranges[owner];
            assert!(lo <= row && row < hi);
            let owners =
                p.ranges.iter().filter(|&&(l, h)| l <= row && row < h).count();
            assert_eq!(owners, 1, "row {row} owned by {owners} shards");
        }
        assert_eq!(plan.shard_mults.iter().sum::<u64>(), plan.total_mults);
        assert_eq!(plan.total_mults, spgemm_flops(&a, &b) / 2);
    });
}
