//! Shared-bandwidth link integration suite (DESIGN.md §11). The
//! contract under test: contention changes simulated *time*, never
//! *results* (any interleaving of jobs through the link yields
//! bit-identical products to serial execution); admission pricing is
//! contention-aware and strictly more accurate than the blind price
//! under a loaded link; SLO deadlines reject unmeetable work at
//! admission with the priced context; and unpriced jobs ride the link
//! for free.

use mlmem_spgemm::bench::experiments::{serve_lhs, serve_rhs};
use mlmem_spgemm::coordinator::{Session, SubmitOptions};
use mlmem_spgemm::gen::scale::ScaleFactor;
use mlmem_spgemm::memory::arch::{knl, p100, Arch, GpuMode, KnlMode};
use mlmem_spgemm::memory::{PendingDemand, FAST};
use mlmem_spgemm::prelude::*;
use mlmem_spgemm::util::proptest::{check, Gen};
use std::sync::Arc;
use std::time::Duration;

fn knl_arch() -> Arc<Arch> {
    Arc::new(knl(KnlMode::Ddr, 64, ScaleFactor::default()))
}

/// The serve experiment's machine: P100 pinned, shrunk so the
/// copy-bound operands stay cheap to simulate.
fn gpu_arch() -> Arc<Arch> {
    Arc::new(p100(GpuMode::Pinned, ScaleFactor::new(1024 * 64)))
}

/// A copy-bound pair sized against the fast pool (staging dominates).
fn copy_bound_pair(arch: &Arch, seed: u64) -> (Arc<Csr>, Arc<Csr>) {
    let usable = arch.spec.pools[FAST.0].usable();
    let b = Arc::new(serve_rhs(usable, seed));
    let a = Arc::new(serve_lhs(usable, b.nrows, seed + 1));
    (a, b)
}

#[test]
fn products_bit_identical_serial_vs_concurrent_link() {
    check("link interleavings preserve products", 8, |g: &mut Gen| {
        let arch = knl_arch();
        let n_jobs = g.usize(2, 5);
        let pairs: Vec<_> = (0..n_jobs).map(|_| g.csr_pair(40, 4)).collect();
        let submit = || SubmitOptions {
            keep_product: true,
            price_admission: true,
            ..Default::default()
        };
        // Serial reference: one worker, submit-and-wait one at a time.
        let serial = Session::builder(Arc::clone(&arch))
            .workers(1)
            .co_schedule(false)
            .build();
        let mut reference = Vec::new();
        for (a, b) in &pairs {
            let ha = serial.register(Arc::new(a.clone()));
            let hb = serial.register(Arc::new(b.clone()));
            let r = serial.spgemm_with(ha, hb, submit()).unwrap().wait().unwrap();
            reference.push(r.c.expect("kept product"));
        }
        // Concurrent: everything in flight at once, all priced through
        // the shared link, co-scheduler free to reorder.
        let concurrent = Session::builder(arch).workers(4).build();
        let handles: Vec<_> = pairs
            .iter()
            .map(|(a, b)| {
                let ha = concurrent.register(Arc::new(a.clone()));
                let hb = concurrent.register(Arc::new(b.clone()));
                concurrent.spgemm_with(ha, hb, submit()).unwrap()
            })
            .collect();
        for (h, want) in handles.into_iter().zip(&reference) {
            let got = h.wait().unwrap().c.expect("kept product");
            assert_eq!(got.rowmap, want.rowmap);
            assert_eq!(got.entries, want.entries);
            assert!(got.approx_eq(want, 0.0), "values must be bit-identical");
        }
    });
}

#[test]
fn slo_rejects_unmeetable_job_and_admits_meetable_one() {
    let session = Session::builder(knl_arch()).workers(1).build();
    let a = session.register(Arc::new(mlmem_spgemm::gen::rhs::random_csr(60, 60, 1, 5, 1)));
    let b = session.register(Arc::new(mlmem_spgemm::gen::rhs::random_csr(60, 60, 1, 5, 2)));
    // A competitor with ten committed simulated seconds sits ahead in
    // the single worker's admission queue.
    let competitor = session
        .shared_link()
        .reserve(PendingDemand { copy_seconds: 10.0, total_seconds: 10.0 });
    let err = session
        .spgemm_with(
            a,
            b,
            SubmitOptions { deadline: Some(Duration::from_secs(5)), ..Default::default() },
        )
        .expect_err("a 5s budget cannot clear 10s of queued work");
    match err {
        MlmemError::AdmissionRejected {
            priced_seconds: Some(p),
            deadline_seconds: Some(d),
            ..
        } => {
            assert!(p > 10.0, "queue wait must dominate the price, got {p}");
            assert_eq!(d, 5.0);
        }
        other => panic!("expected a priced rejection, got {other:?}"),
    }
    // With the competitor gone the same job meets a generous SLO.
    drop(competitor);
    let r = session
        .spgemm_with(
            a,
            b,
            SubmitOptions { deadline: Some(Duration::from_secs(60)), ..Default::default() },
        )
        .expect("idle link admits")
        .wait()
        .expect("admitted job completes within its SLO");
    assert!(r.c_nnz > 0);
    session.drain();
    let m = session.metrics();
    assert_eq!((m.completed, m.rejected, m.slo_misses), (1, 1, 0));
}

#[test]
fn aware_price_beats_blind_under_a_saturated_link() {
    let arch = gpu_arch();
    let (a, b) = copy_bound_pair(&arch, 7);
    let session = Session::builder(Arc::clone(&arch))
        .workers(2)
        .operand_cache(false)
        .build();
    let (ha, hb) = (session.register(a), session.register(b));
    // A foreign stream holds the link for the whole run: reserved AND
    // attached, with a copy budget it never drains — deterministic
    // contention without racing a second worker thread.
    let foreign = session
        .shared_link()
        .reserve(PendingDemand { copy_seconds: 1e6, total_seconds: 1e6 })
        .attach();
    let h = session
        .spgemm_with(ha, hb, SubmitOptions { price_admission: true, ..Default::default() })
        .expect("admitted");
    let t = *h.ticket().expect("priced submission carries a ticket");
    assert_eq!(t.pending_jobs, 1, "the foreign stream is committed load");
    assert!(t.committed_copy_seconds >= 1e6);
    assert!(t.aware_seconds > t.blind_seconds, "contention must be priced in");
    let r = h.wait().expect("job ok");
    let actual = r.report.seconds;
    assert!(
        r.report.link_stall_seconds > 0.0,
        "the arbiter actually charged contention"
    );
    let blind_err = ((t.blind_seconds - actual) / actual).abs();
    let aware_err = ((t.aware_seconds - actual) / actual).abs();
    assert!(
        aware_err < blind_err,
        "aware error {aware_err:.4} must beat blind {blind_err:.4} (actual {actual:.6}s)"
    );
    drop(foreign);
}

#[test]
fn unpriced_jobs_ride_the_link_free() {
    // The same job on a fresh session, with and without a saturated
    // link: an unpriced submission (Auto, no deadline, no price flag,
    // cold pair cache) never touches the arbiter, so its simulated time
    // is bit-identical and it records no link stall.
    let run = |saturate: bool| {
        let arch = gpu_arch();
        let (a, b) = copy_bound_pair(&arch, 11);
        let session = Session::builder(arch).workers(1).operand_cache(false).build();
        let (ha, hb) = (session.register(a), session.register(b));
        let _foreign = saturate.then(|| {
            session
                .shared_link()
                .reserve(PendingDemand { copy_seconds: 1e6, total_seconds: 1e6 })
                .attach()
        });
        let r = session.spgemm(ha, hb).unwrap().wait().unwrap();
        (r.report.seconds, r.report.link_stall_seconds)
    };
    let (clean_s, clean_stall) = run(false);
    let (loaded_s, loaded_stall) = run(true);
    assert_eq!(clean_s, loaded_s, "a saturated link must not slow unpriced jobs");
    assert_eq!(clean_stall, 0.0);
    assert_eq!(loaded_stall, 0.0);
}
