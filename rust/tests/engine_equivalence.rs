//! Engine-equivalence suite: every engine behind the unified `Engine`
//! trait must produce the same sorted CSR product as the dense reference
//! across random, stencil, and power-law inputs — and the pipelined
//! GPU-chunk engine must beat the serial chunk driver on a problem whose
//! B exceeds the fast pool, with an identical product (the PR's
//! acceptance criterion).

use mlmem_spgemm::chunk::gpu_chunked_sim;
use mlmem_spgemm::engine::{gpu_pipelined_sim, Engine, EngineKind, Problem};
use mlmem_spgemm::gen::rhs::{random_csr, uniform_degree};
use mlmem_spgemm::gen::scale::ScaleFactor;
use mlmem_spgemm::kkmem::SpgemmOptions;
use mlmem_spgemm::memory::arch::{knl, p100, GpuMode, KnlMode};
use mlmem_spgemm::memory::{MemSim, FAST};
use mlmem_spgemm::sparse::ops::spgemm_reference;
use mlmem_spgemm::sparse::Csr;
use mlmem_spgemm::util::proptest::{check, Gen};
use std::sync::Arc;

/// Run every engine kind on (a, b) and assert all sorted products are
/// structurally identical and numerically equal to the dense reference.
fn assert_engines_agree(a: &Csr, b: &Csr, label: &str) {
    let mut reference = spgemm_reference(a, b);
    reference.sort_rows();
    let knl_arch = Arc::new(knl(KnlMode::Ddr, 256, ScaleFactor::default()));
    let gpu_arch = Arc::new(p100(GpuMode::Pinned, ScaleFactor::default()));
    // A budget that forces real chunking on the chunk engines.
    let budget = (b.size_bytes() / 3).max(256);
    let problem = Problem::new(a, b);
    let mut products: Vec<(String, Csr)> = Vec::new();
    for kind in EngineKind::ALL {
        let archs: Vec<Arc<_>> = match kind {
            EngineKind::KnlChunk => vec![Arc::clone(&knl_arch)],
            EngineKind::GpuChunk => vec![Arc::clone(&gpu_arch)],
            // The pipelined engine has a KNL and a GPU flavour: run both.
            EngineKind::Pipelined => vec![Arc::clone(&knl_arch), Arc::clone(&gpu_arch)],
            _ => vec![Arc::clone(&knl_arch)],
        };
        for arch in archs {
            let name = format!("{}@{}", kind.name(), arch.spec.name);
            let eng = kind
                .build(arch, SpgemmOptions::default(), Some(budget))
                .unwrap_or_else(|e| panic!("{label}/{name}: build: {e}"));
            let rep = eng
                .execute(&problem)
                .unwrap_or_else(|e| panic!("{label}/{name}: {e}"));
            let mut c = rep.c;
            c.sort_rows();
            assert!(
                c.approx_eq(&reference, 1e-9),
                "{label}/{name}: product diverges from reference"
            );
            products.push((name, c));
        }
    }
    // All engines share the symbolic structure: sorted rowmaps and column
    // sets must be *identical*, values equal to fp-reassociation noise.
    let (first_name, first) = &products[0];
    for (name, c) in &products[1..] {
        assert_eq!(
            c.rowmap, first.rowmap,
            "{label}: rowmap of {name} != {first_name}"
        );
        assert_eq!(
            c.entries, first.entries,
            "{label}: entries of {name} != {first_name}"
        );
        for (i, (v, w)) in c.values.iter().zip(&first.values).enumerate() {
            assert!(
                (v - w).abs() <= 1e-9 * w.abs().max(1.0),
                "{label}: value[{i}] of {name} = {v} vs {first_name} = {w}"
            );
        }
    }
}

#[test]
fn engines_agree_on_random_inputs() {
    check("engines agree (random)", 6, |g: &mut Gen| {
        let m = g.usize(10, 50);
        let k = g.usize(10, 50);
        let n = g.usize(10, 50);
        let a = random_csr(m, k, 1, 6, g.u64());
        let b = random_csr(k, n, 1, 6, g.u64());
        assert_engines_agree(&a, &b, "random");
    });
}

#[test]
fn engines_agree_on_stencil_inputs() {
    let g = mlmem_spgemm::gen::stencil::Grid::new(6, 6, 6);
    let a = mlmem_spgemm::gen::stencil::laplace3d(g);
    assert_engines_agree(&a, &a, "laplace3d");
    let g2 = mlmem_spgemm::gen::stencil::Grid::new(5, 5, 5);
    let brick = mlmem_spgemm::gen::stencil::brick3d(g2);
    assert_engines_agree(&brick, &brick, "brick3d");
}

#[test]
fn engines_agree_on_power_law_inputs() {
    // RMAT with graph500 parameters: heavy-tailed, hub-dominated rows —
    // the skew that stresses accumulators and partitioners.
    let adj = mlmem_spgemm::gen::graphs::graph500(6, 8, 42);
    assert_engines_agree(&adj, &adj, "rmat-aa");
    let rect = uniform_degree(adj.ncols, 40, 3, 7);
    assert_engines_agree(&adj, &rect, "rmat-rect");
}

/// Acceptance criterion: on a problem whose B exceeds the fast pool's
/// usable capacity, the pipelined GPU-chunk engine simulates strictly
/// faster than the serial chunk driver while producing the same product.
#[test]
fn pipelined_gpu_beats_serial_when_b_exceeds_fast_pool() {
    let a = uniform_degree(1000, 100_000, 64, 1);
    let b = uniform_degree(100_000, 500, 16, 2);
    let scale = ScaleFactor::default();
    let arch = p100(GpuMode::Pinned, scale);
    let fast_usable = arch.spec.pools[FAST.0].usable();
    assert!(
        b.size_bytes() > fast_usable,
        "precondition: B ({} B) must exceed the fast pool's usable {} B",
        b.size_bytes(),
        fast_usable
    );
    let opts = SpgemmOptions::default();

    let mut serial_sim = MemSim::new(arch.spec.clone());
    let serial = gpu_chunked_sim(&mut serial_sim, &a, &b, u64::MAX, &opts).unwrap();
    let serial_rep = serial_sim.finish();

    let mut pipe_sim = MemSim::new(arch.spec.clone());
    let piped = gpu_pipelined_sim(&mut pipe_sim, &a, &b, u64::MAX, &opts).unwrap();
    let pipe_rep = pipe_sim.finish();

    // Identical product (sorted structure equal, values to fp noise).
    let mut cs = serial.c.clone();
    cs.sort_rows();
    let mut cp = piped.c.clone();
    cp.sort_rows();
    assert_eq!(cs.rowmap, cp.rowmap);
    assert_eq!(cs.entries, cp.entries);
    assert!(cp.approx_eq(&cs, 1e-9));

    // Strictly lower simulated time, with real transfer time hidden.
    assert!(
        pipe_rep.seconds < serial_rep.seconds,
        "pipelined {} s !< serial {} s",
        pipe_rep.seconds,
        serial_rep.seconds
    );
    let hidden = pipe_rep.async_copy_seconds - pipe_rep.overlap_stall_seconds;
    assert!(hidden > 0.0, "no transfer time was hidden");
    // The serial driver exposes every staging copy; the pipelined one
    // must expose strictly less copy+stall time in total.
    assert!(
        pipe_rep.copy_seconds + pipe_rep.overlap_stall_seconds
            < serial_rep.copy_seconds,
        "exposed transfer time did not shrink: {} + {} vs {}",
        pipe_rep.copy_seconds,
        pipe_rep.overlap_stall_seconds,
        serial_rep.copy_seconds
    );
}

/// The pipelined engine through the `Engine` trait reports its chunking.
#[test]
fn pipelined_engine_reports_parts_and_sim() {
    let a = uniform_degree(200, 4000, 16, 3);
    let b = uniform_degree(4000, 200, 8, 4);
    let arch = Arc::new(knl(KnlMode::Ddr, 256, ScaleFactor::default()));
    let eng = EngineKind::Pipelined
        .build(arch, SpgemmOptions::default(), Some(b.size_bytes() / 4))
        .unwrap();
    let rep = eng.execute(&Problem::new(&a, &b)).unwrap();
    assert!(rep.n_parts_b >= 3, "got {} parts", rep.n_parts_b);
    assert!(rep.copied_bytes >= b.size_bytes());
    let sim = rep.sim.expect("simulated engine");
    assert!(sim.async_copy_seconds > 0.0);
}
