//! Golden-file tests for the planner's `--explain` surfaces: the
//! candidate tables behind `spgemm --explain` and the per-hop output of
//! `chain`. The snapshots are *structural* — candidate sets, ordering,
//! chosen-row invariants, hop decisions, residency markers — rather than
//! raw floating-point columns, so they pin planner-output regressions
//! (a candidate disappearing, a gate flipping, residency not engaging)
//! without breaking on every cost-model retune.
//!
//! Regenerate with `GOLDEN_BLESS=1 cargo test -q --test explain_golden`.

use mlmem_spgemm::coordinator::{explain_spgemm, PlannerOptions, Session, SubmitOptions};
use mlmem_spgemm::gen::rhs::uniform_degree;
use mlmem_spgemm::gen::scale::ScaleFactor;
use mlmem_spgemm::memory::arch::{knl, KnlMode};
use mlmem_spgemm::memory::FAST;
use std::path::PathBuf;
use std::sync::Arc;

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/golden")
        .join(name)
}

fn check_golden(name: &str, actual: &str) {
    let path = golden_path(name);
    if std::env::var("GOLDEN_BLESS").is_ok() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, actual).unwrap();
        return;
    }
    let expected = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("missing golden file {} ({e}); run with GOLDEN_BLESS=1", path.display()));
    assert_eq!(
        actual,
        expected.as_str(),
        "golden mismatch for {name}; re-bless with GOLDEN_BLESS=1 if intended"
    );
}

/// `spgemm --explain` on a fixed seed and a shrunken KNL fast pool that
/// forces the flat-fast and data-placement candidates out: the snapshot
/// pins the surviving candidate set, its order, and the table's
/// structural invariants.
#[test]
fn spgemm_explain_candidate_table_is_stable() {
    let mut arch = knl(KnlMode::Ddr, 256, ScaleFactor::default());
    arch.spec.pools[FAST.0].capacity = 256 * 1024; // usable = 179 KiB
    let arch = Arc::new(arch);
    let a = uniform_degree(300, 2000, 8, 5);
    let b = uniform_degree(2000, 600, 6, 6);
    assert!(
        b.size_bytes() > arch.spec.pools[FAST.0].usable().saturating_sub(1 << 16),
        "construction drifted: B must rule out the DP candidate"
    );
    let rows = explain_spgemm(&a, &b, &arch, &PlannerOptions::default());
    let mut out = String::new();
    out.push_str(&format!("machine={}\n", arch.spec.name));
    out.push_str(&format!(
        "candidates={}\n",
        rows.iter().map(|r| r.label.as_str()).collect::<Vec<_>>().join(",")
    ));
    out.push_str(&format!("rows={}\n", rows.len()));
    out.push_str(&format!(
        "chosen-count={}\n",
        rows.iter().filter(|r| r.chosen).count()
    ));
    out.push_str(&format!(
        "all-predictions-positive={}\n",
        rows.iter().all(|r| r.predicted.total_seconds() > 0.0)
    ));
    out.push_str(&format!(
        "all-actuals-finite={}\n",
        rows.iter().all(|r| r.actual_seconds.is_finite() && r.actual_seconds > 0.0)
    ));
    out.push_str(&format!(
        "all-passes-at-least-one={}\n",
        rows.iter().all(|r| r.predicted.passes >= 1)
    ));
    check_golden("spgemm_explain_knl.txt", &out);
}

/// The serve path's memo provenance (DESIGN.md §13) on a fixed job
/// sequence: a repeated pair replays as a memo hit, re-registering an
/// operand invalidates its products and forces a recompute, and
/// concurrent identical jobs coalesce onto one computation. Structural:
/// provenance markers and result-cache counters, never timings.
#[test]
fn serve_memo_provenance_is_stable() {
    let arch = Arc::new(knl(KnlMode::Ddr, 64, ScaleFactor::default()));
    let session = Session::builder(arch).workers(1).build();
    let small = |seed| Arc::new(mlmem_spgemm::gen::rhs::random_csr(60, 60, 1, 5, seed));
    let a = session.register(small(81));
    let b = session.register(small(82));
    let c = session.register(small(83));
    let mut serial = Vec::new();
    for (x, y) in [(a, b), (a, b), (a, c), (a, c)] {
        serial.push(session.spgemm(x, y).unwrap().wait().unwrap().provenance.name());
    }
    session.reregister(a, small(84)).unwrap();
    let invalidated = session.metrics().memo.invalidated;
    let after = session.spgemm(a, b).unwrap().wait().unwrap().provenance.name();
    // Concurrent identical jobs on operands big enough (real
    // milliseconds of simulation) that the single worker is still
    // grinding the primary when the repeats arrive and attach.
    let d = session.register(Arc::new(mlmem_spgemm::gen::rhs::random_csr(600, 600, 6, 10, 85)));
    let e = session.register(Arc::new(mlmem_spgemm::gen::rhs::random_csr(600, 600, 6, 10, 86)));
    let keep = || SubmitOptions { keep_product: true, ..Default::default() };
    let handles: Vec<_> = (0..3).map(|_| session.spgemm_with(d, e, keep()).unwrap()).collect();
    let results: Vec<_> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
    let concurrent: Vec<_> = results.iter().map(|r| r.provenance.name()).collect();
    let first = results[0].c.as_ref().expect("primary keeps C");
    let identical = results[1..].iter().all(|r| {
        let w = r.c.as_ref().expect("waiters get the shared product");
        w.rowmap == first.rowmap && w.entries == first.entries && w.approx_eq(first, 0.0)
    });
    session.drain();
    let m = session.metrics();
    let mut out = String::new();
    out.push_str(&format!("serial.provenance={}\n", serial.join(",")));
    out.push_str(&format!("reregister.invalidated={invalidated}\n"));
    out.push_str(&format!("after-invalidate.provenance={after}\n"));
    out.push_str(&format!("concurrent.provenance={}\n", concurrent.join(",")));
    out.push_str(&format!(
        "concurrent.bit-identical={}\n",
        if identical { "yes" } else { "no" }
    ));
    out.push_str(&format!(
        "memo.counters=hits:{},misses:{},coalesced:{},products:{},invalidated:{}\n",
        m.memo.hits, m.memo.misses, m.memo.coalesced, m.memo.products, m.memo.invalidated
    ));
    check_golden("serve_memo_provenance.txt", &out);
}

/// The chain planner's output on a fixed 3-chain whose right fold is
/// structurally forced (M₃ is thin, so `M₂·M₃` is a far smaller
/// intermediate and both right-order hops do strictly less work):
/// both orders scored, both hops flat-fast, the second hop consuming
/// its intermediate resident-as-B with no promotion — which also pins
/// that the duplicate `pipelined-knl` candidate is dropped for a
/// resident B while the first hop keeps the full candidate set.
#[test]
fn chain_explain_hop_tables_are_stable() {
    let arch = Arc::new(knl(KnlMode::Ddr, 64, ScaleFactor::default()));
    let session = Session::builder(arch).workers(1).build();
    let m1 = session.register(Arc::new(uniform_degree(200, 200, 6, 1)));
    let m2 = session.register(Arc::new(uniform_degree(200, 200, 6, 2)));
    let m3 = session.register(Arc::new(mlmem_spgemm::gen::rhs::random_csr(200, 4, 1, 1, 3)));
    let result = session.execute_chain(&[m1, m2, m3]).expect("chain succeeds");
    let chain = result.chain.as_ref().expect("summary");
    let mut out = String::new();
    out.push_str(&format!("hops={}\n", chain.hops.len()));
    out.push_str(&format!("orders-scored={}\n", chain.order_scores.len()));
    out.push_str(&format!("assoc={}\n", chain.assoc.name()));
    out.push_str(&format!("prediction-present={}\n", result.predicted.is_some()));
    for (i, h) in chain.hops.iter().enumerate() {
        out.push_str(&format!("hop{i}.decision={}\n", h.decision.name()));
        out.push_str(&format!(
            "hop{i}.resident={}\n",
            if h.residency.any() { "yes" } else { "no" }
        ));
        out.push_str(&format!(
            "hop{i}.promoted={}\n",
            if h.promote_seconds > 0.0 { "yes" } else { "no" }
        ));
        out.push_str(&format!(
            "hop{i}.candidates={}\n",
            h.candidates.iter().map(|c| c.label.as_str()).collect::<Vec<_>>().join(",")
        ));
    }
    check_golden("chain_explain_knl.txt", &out);
}
