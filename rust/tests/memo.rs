//! Serve-path memoization integration suite (DESIGN.md §13). The
//! contract under test: the result cache changes *work*, never
//! *results* — a memoized serve stream is bit-identical job-by-job to
//! the memo-off stream across interleavings and byte budgets (including
//! 0 and smaller-than-any-product); re-registering an operand
//! invalidates every cached product using it; N identical concurrent
//! jobs coalesce onto exactly one computation; and a waiter's own
//! cancel/deadline never touches the shared run.

use mlmem_spgemm::coordinator::{Provenance, Session, SubmitOptions};
use mlmem_spgemm::error::JobControl;
use mlmem_spgemm::gen::scale::ScaleFactor;
use mlmem_spgemm::memory::arch::{knl, Arch, KnlMode};
use mlmem_spgemm::prelude::*;
use mlmem_spgemm::util::proptest::{check, Gen};
use std::sync::Arc;
use std::time::Duration;

fn arch() -> Arc<Arch> {
    Arc::new(knl(KnlMode::Ddr, 64, ScaleFactor::default()))
}

fn square(n: usize, deg: usize, seed: u64) -> Arc<Csr> {
    Arc::new(mlmem_spgemm::gen::rhs::random_csr(n, n, 0, deg, seed))
}

/// Big enough that the simulated run takes real milliseconds, so
/// submissions racing the single worker deterministically find the
/// primary still in flight.
fn slow_operand(seed: u64) -> Arc<Csr> {
    Arc::new(mlmem_spgemm::gen::rhs::random_csr(600, 600, 6, 10, seed))
}

fn keep() -> SubmitOptions {
    SubmitOptions { keep_product: true, ..Default::default() }
}

fn assert_same_product(want: &Csr, got: &Csr, label: &str) {
    assert_eq!(want.rowmap, got.rowmap, "{label}: rowmap diverged");
    assert_eq!(want.entries, got.entries, "{label}: entries diverged");
    assert!(want.approx_eq(got, 0.0), "{label}: values must be bit-identical");
}

/// Replay `stream` (pairs of indices into `mats`) through one session,
/// submitting `chunk` jobs at a time before waiting on them, and return
/// each job's product in stream order.
fn run_stream(
    arch: &Arc<Arch>,
    mats: &[Arc<Csr>],
    stream: &[(usize, usize)],
    memo: bool,
    budget: Option<u64>,
    chunk: usize,
) -> Vec<(usize, usize, Csr)> {
    let mut builder = Session::builder(Arc::clone(arch))
        .workers(1)
        .max_pending(stream.len() + 2)
        .memoize(memo);
    if let Some(bytes) = budget {
        builder = builder.result_cache(bytes);
    }
    let session = builder.build();
    let handles: Vec<_> = mats.iter().map(|m| session.register(Arc::clone(m))).collect();
    let mut out = Vec::new();
    for block in stream.chunks(chunk.max(1)) {
        let hs: Vec<_> = block
            .iter()
            .map(|&(i, j)| {
                session.spgemm_with(handles[i], handles[j], keep()).expect("admitted")
            })
            .collect();
        for h in hs {
            let r = h.wait().expect("job ok");
            out.push((r.c_nrows, r.c_nnz, r.c.expect("keep_product attaches C")));
        }
    }
    out
}

#[test]
fn memo_on_streams_are_bit_identical_to_memo_off() {
    check("memo on == memo off, job by job", 6, |g: &mut Gen| {
        let arch = arch();
        let n = g.usize(20, 48);
        let mats: Vec<_> = (0..3).map(|_| square(n, g.usize(1, 5), g.u64())).collect();
        let len = g.usize(4, 9);
        let stream: Vec<(usize, usize)> =
            (0..len).map(|_| (g.usize(0, 2), g.usize(0, 2))).collect();
        // Budgets cover: session default, disabled-by-budget (0), smaller
        // than any product (1 byte), and effectively unbounded.
        let budget = *g.pick(&[None, Some(0), Some(1), Some(1 << 40)]);
        let chunk = g.usize(1, len);
        let off = run_stream(&arch, &mats, &stream, false, None, 1);
        let on = run_stream(&arch, &mats, &stream, true, budget, chunk);
        assert_eq!(off.len(), on.len());
        for (k, (o, m)) in off.iter().zip(&on).enumerate() {
            assert_eq!((o.0, o.1), (m.0, m.1), "job {k}: shape/nnz diverged");
            assert_same_product(&o.2, &m.2, &format!("job {k}"));
        }
    });
}

#[test]
fn reregistration_invalidates_every_product_using_the_operand() {
    let session = Session::builder(arch()).workers(1).build();
    let a = session.register(square(40, 4, 1));
    let b = session.register(square(40, 4, 2));
    let c = session.register(square(40, 4, 3));
    for (x, y) in [(a, b), (b, c), (a, c)] {
        session.spgemm(x, y).unwrap().wait().unwrap();
    }
    let m = session.metrics();
    assert_eq!((m.memo.products, m.memo.misses, m.memo.hits), (3, 3, 0));

    // Re-registering B drops (A,B) and (B,C) but spares (A,C).
    session.reregister(b, square(40, 4, 9)).unwrap();
    assert_eq!(session.metrics().memo.invalidated, 2);

    let r_ab = session.spgemm(a, b).unwrap().wait().unwrap();
    let r_bc = session.spgemm(b, c).unwrap().wait().unwrap();
    let r_ac = session.spgemm(a, c).unwrap().wait().unwrap();
    assert_eq!(r_ab.provenance, Provenance::Computed, "stale (A,B) served");
    assert_eq!(r_bc.provenance, Provenance::Computed, "stale (B,C) served");
    assert_eq!(r_ac.provenance, Provenance::MemoHit, "(A,C) was needlessly dropped");
    session.drain();
    let m = session.metrics();
    assert_eq!((m.memo.invalidated, m.memo.hits, m.memo.products), (2, 1, 5));
}

#[test]
fn concurrent_identical_jobs_coalesce_onto_one_computation() {
    let session = Session::builder(arch()).workers(1).build();
    let a = session.register(slow_operand(40));
    let b = session.register(slow_operand(41));
    let n = 4;
    let handles: Vec<_> =
        (0..n).map(|_| session.spgemm_with(a, b, keep()).expect("admitted")).collect();
    let results: Vec<_> = handles.into_iter().map(|h| h.wait().expect("ok")).collect();
    let prov: Vec<_> = results.iter().map(|r| r.provenance).collect();
    assert_eq!(prov[0], Provenance::Computed);
    assert!(
        prov[1..].iter().all(|p| *p == Provenance::Coalesced),
        "all repeats must attach to the in-flight run, got {prov:?}"
    );
    let first = results[0].c.as_ref().expect("primary keeps C");
    for (k, r) in results[1..].iter().enumerate() {
        let c = r.c.as_ref().expect("waiters get the shared product");
        assert_same_product(first, c, &format!("waiter {k}"));
    }
    session.drain();
    let m = session.metrics();
    assert_eq!(m.memo.products, 1, "exactly one computation for {n} jobs");
    assert_eq!((m.memo.misses, m.memo.coalesced), (1, n as u64 - 1));
    assert_eq!((m.submitted, m.completed), (n as u64, n as u64));
    assert_eq!(session.symbolic_passes(), 1);
}

#[test]
fn waiter_cancel_and_deadline_do_not_affect_the_shared_run() {
    let session = Session::builder(arch()).workers(1).build();
    let a = session.register(slow_operand(50));
    let b = session.register(slow_operand(51));
    let primary = session.spgemm_with(a, b, keep()).expect("admitted");
    // A waiter whose 1 ms budget expires while the shared run (real
    // milliseconds of simulation) grinds on...
    let doomed = session
        .spgemm_with(
            a,
            b,
            SubmitOptions { deadline: Some(Duration::from_millis(1)), ..Default::default() },
        )
        .expect("coalesced submissions are not SLO-priced");
    // ...and one cancelled outright after attaching.
    let flag = JobControl::new();
    let cancelled = session
        .spgemm_with(a, b, SubmitOptions { control: Some(flag.clone()), ..Default::default() })
        .expect("admitted");
    flag.cancel();
    let healthy = session.spgemm_with(a, b, keep()).expect("admitted");

    let r_primary = primary.wait().expect("the shared run itself must survive");
    assert!(matches!(doomed.wait(), Err(MlmemError::DeadlineExceeded)));
    assert!(matches!(cancelled.wait(), Err(MlmemError::Cancelled)));
    let r_healthy = healthy.wait().expect("an unrelated waiter is unaffected");
    assert_eq!(r_primary.provenance, Provenance::Computed);
    assert_eq!(r_healthy.provenance, Provenance::Coalesced);
    assert_same_product(
        r_primary.c.as_ref().unwrap(),
        r_healthy.c.as_ref().unwrap(),
        "healthy waiter",
    );
    session.drain();
    let m = session.metrics();
    assert_eq!((m.memo.products, m.memo.coalesced), (1, 3));
    assert_eq!(m.completed, 2, "primary + healthy waiter");
    assert_eq!(m.cancelled, 2, "doomed + cancelled waiters, charged to them alone");
    assert_eq!(m.failed, 0);
}

#[test]
fn zero_budget_keeps_coalescing_correct_without_caching() {
    let session = Session::builder(arch()).workers(1).result_cache(0).build();
    let a = session.register(square(40, 4, 60));
    let b = session.register(square(40, 4, 61));
    let r1 = session.spgemm_with(a, b, keep()).unwrap().wait().unwrap();
    let r2 = session.spgemm_with(a, b, keep()).unwrap().wait().unwrap();
    // Nothing fit the cache, so both serial submissions computed...
    assert_eq!(r1.provenance, Provenance::Computed);
    assert_eq!(r2.provenance, Provenance::Computed);
    assert_same_product(r1.c.as_ref().unwrap(), r2.c.as_ref().unwrap(), "recompute");
    session.drain();
    let m = session.metrics();
    assert_eq!((m.memo.hits, m.memo.products), (0, 2));
    assert_eq!((m.memo.resident_entries, m.memo.resident_bytes), (0, 0));
}

#[test]
fn result_cache_budget_evicts_and_stays_within_bytes() {
    // Probe the two products' cached sizes with an ample budget...
    let mats = [square(40, 4, 70), square(40, 4, 71), square(40, 4, 72)];
    let probe = Session::builder(arch()).workers(1).build();
    let pa = probe.register(Arc::clone(&mats[0]));
    let pb = probe.register(Arc::clone(&mats[1]));
    let pc = probe.register(Arc::clone(&mats[2]));
    probe.spgemm(pa, pb).unwrap().wait().unwrap();
    let s1 = probe.metrics().memo.resident_bytes;
    probe.spgemm(pa, pc).unwrap().wait().unwrap();
    let s2 = probe.metrics().memo.resident_bytes - s1;
    assert!(s1 > 0 && s2 > 0);

    // ...then rerun under a budget that holds either product but not
    // both: the second admission must evict the first, and the gauge
    // never exceeds the budget.
    let budget = s1 + s2 - 1;
    let session = Session::builder(arch()).workers(1).result_cache(budget).build();
    let a = session.register(Arc::clone(&mats[0]));
    let b = session.register(Arc::clone(&mats[1]));
    let c = session.register(Arc::clone(&mats[2]));
    session.spgemm(a, b).unwrap().wait().unwrap();
    session.spgemm(a, c).unwrap().wait().unwrap();
    let m = session.metrics();
    assert_eq!(m.memo.evictions, 1, "(A,B) must make room for (A,C)");
    assert_eq!(m.memo.evicted_bytes, s1);
    assert_eq!(m.memo.resident_bytes, s2);
    assert!(m.memo.resident_bytes <= budget);
    // The resident pair replays; the evicted one recomputes (and its
    // re-admission in turn displaces the resident product).
    let r_ac = session.spgemm(a, c).unwrap().wait().unwrap();
    assert_eq!(r_ac.provenance, Provenance::MemoHit);
    let r_ab = session.spgemm(a, b).unwrap().wait().unwrap();
    assert_eq!(r_ab.provenance, Provenance::Computed);
}
