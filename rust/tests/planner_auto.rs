//! Predictive Auto-planner suite: the DESIGN.md §4 regression (serial
//! chunking must win the C-dominated budget band) plus a property that
//! Auto's simulated time never trails the best explicit policy by more
//! than a small tolerance.

use mlmem_spgemm::coordinator::{
    execute, explain_spgemm, Decision, Job, JobKind, JobResult, PlannerOptions, Policy,
};
use mlmem_spgemm::gen::rhs::uniform_degree;
use mlmem_spgemm::gen::scale::ScaleFactor;
use mlmem_spgemm::memory::arch::{knl, Arch, KnlMode};
use mlmem_spgemm::memory::FAST;
use mlmem_spgemm::sparse::Csr;
use mlmem_spgemm::util::proptest::{check, Gen};
use std::sync::Arc;

fn run_policy(a: &Arc<Csr>, b: &Arc<Csr>, arch: &Arc<Arch>, policy: Policy, id: u64) -> JobResult {
    let job = Job::new(
        id,
        JobKind::Spgemm { a: Arc::clone(a), b: Arc::clone(b) },
        Arc::clone(arch),
        policy,
    );
    execute(&job, &PlannerOptions::default())
        .unwrap_or_else(|e| panic!("policy {policy:?}: {e}"))
}

/// The DESIGN.md §4 defect, pinned: a C-dominated KNL problem whose B
/// sits in the budget band where the pipelined executor's `usable/2` cut
/// doubles the pass count. Each extra pass reprocesses the large partial
/// C from DDR, which costs far more than the overlapped B staging saves,
/// so serial `Chunked` simulates faster — and the predictive Auto planner
/// must now select it (the old Auto hardwired `Pipelined`).
#[test]
fn auto_selects_serial_chunking_on_c_dominated_band() {
    // Shrink the fast pool so the regression runs at test size: usable
    // becomes 0.7 * 2 MiB = ~1.43 MiB.
    let mut arch = knl(KnlMode::Ddr, 256, ScaleFactor::default());
    arch.spec.pools[FAST.0].capacity = 2 * 1024 * 1024;
    let arch = Arc::new(arch);
    let usable = arch.spec.pools[FAST.0].usable();

    // A: 1000×7600, degree 38; B: 7600×60000, degree 30. The wide, nearly
    // collision-free product has ~1.1M nonzeros (~13.5 MB): C dominates
    // both operands by an order of magnitude. B is ~2.8 MB — just under
    // two fast-pool budgets, so the serial cut gives 2 passes while the
    // pipelined usable/2 cut gives 4, and each extra pass reprocesses the
    // 13.5 MB partial from DDR against a ~31 µs staged-copy saving.
    let a = Arc::new(uniform_degree(1000, 7_600, 38, 11));
    let b = Arc::new(uniform_degree(7_600, 60_000, 30, 12));
    let b_bytes = b.size_bytes();
    assert!(
        b_bytes > usable && b_bytes < 2 * usable,
        "construction drifted: B = {b_bytes}, usable = {usable}"
    );

    let auto = run_policy(&a, &b, &arch, Policy::Auto, 1);
    let serial = run_policy(&a, &b, &arch, Policy::Chunked { fast_budget: usable }, 2);
    let piped = run_policy(&a, &b, &arch, Policy::Pipelined { fast_budget: None }, 3);

    // The pipelined cut really did add passes, and really did lose.
    let (serial_parts, piped_parts) = match (&serial.decision, &piped.decision) {
        (Decision::ChunkedKnl { parts }, Decision::Pipelined { parts_b, .. }) => {
            (*parts, *parts_b)
        }
        other => panic!("unexpected explicit decisions: {other:?}"),
    };
    assert!(piped_parts > serial_parts, "{piped_parts} !> {serial_parts}");
    assert!(
        serial.report.seconds < piped.report.seconds,
        "defect premise gone: serial {} !< pipelined {}",
        serial.report.seconds,
        piped.report.seconds
    );

    // The fix: Auto predicts the crossover and picks the serial plan.
    match auto.decision {
        Decision::ChunkedKnl { parts } => assert_eq!(parts, serial_parts),
        other => panic!("Auto picked {other:?} instead of serial chunking"),
    }
    assert!(
        auto.report.seconds <= piped.report.seconds,
        "Auto {} !<= pipelined {}",
        auto.report.seconds,
        piped.report.seconds
    );
    // Identical plan -> identical simulated time (same driver, same cut).
    let rel = (auto.report.seconds - serial.report.seconds).abs() / serial.report.seconds;
    assert!(rel < 1e-9, "Auto did not run the serial plan it chose (rel {rel})");
    // Prediction and the scored table are recorded for observability.
    assert!(auto.predicted.is_some());
    assert!(auto.candidates.iter().any(|c| c.label == "chunked-knl"));
    assert!(auto.candidates.iter().any(|c| c.label == "pipelined-knl"));
}

/// On the same C-dominated input, `--explain`'s backing function must
/// run every candidate and report finite predicted-vs-actual pairs, with
/// the argmin marked.
#[test]
fn explain_covers_the_regression_candidates() {
    let mut arch = knl(KnlMode::Ddr, 256, ScaleFactor::default());
    arch.spec.pools[FAST.0].capacity = 2 * 1024 * 1024;
    let arch = Arc::new(arch);
    let a = uniform_degree(300, 5_000, 40, 13);
    let b = uniform_degree(5_000, 20_000, 20, 14);
    let rows = explain_spgemm(&a, &b, &arch, &PlannerOptions::default());
    assert!(rows.len() >= 3, "{} candidates", rows.len());
    assert_eq!(rows.iter().filter(|r| r.chosen).count(), 1);
    for r in &rows {
        assert!(r.predicted.total_seconds() > 0.0, "{}", r.label);
        assert!(
            r.actual_seconds.is_finite() && r.actual_seconds > 0.0,
            "{} did not run",
            r.label
        );
    }
}

/// Property: Auto is never worse than the best explicit policy by more
/// than 5%. On problems that fit the fast pool Auto additionally has the
/// flat-fast plan available, so it usually wins outright; the tolerance
/// absorbs prediction error elsewhere.
#[test]
fn prop_auto_within_tolerance_of_best_explicit() {
    check("auto_beats_explicit_policies", 12, |g: &mut Gen| {
        let arch = Arc::new(knl(KnlMode::Ddr, 64, ScaleFactor::default()));
        let (a, b) = g.csr_pair(80, 8);
        let (a, b) = (Arc::new(a), Arc::new(b));
        let usable = arch.spec.pools[FAST.0].usable();
        let auto = run_policy(&a, &b, &arch, Policy::Auto, 1);
        let explicit = [
            run_policy(&a, &b, &arch, Policy::Flat, 2),
            run_policy(&a, &b, &arch, Policy::DataPlacement, 3),
            run_policy(&a, &b, &arch, Policy::Chunked { fast_budget: usable }, 4),
            run_policy(&a, &b, &arch, Policy::Pipelined { fast_budget: None }, 5),
        ];
        let best = explicit
            .iter()
            .map(|r| r.report.seconds)
            .fold(f64::INFINITY, f64::min);
        assert!(
            auto.report.seconds <= best * 1.05,
            "Auto {} > best explicit {} * 1.05 (decision {})",
            auto.report.seconds,
            best,
            auto.decision.name()
        );
    });
}
