//! Cross-module property tests (our `util::proptest` mini-framework):
//! the invariants DESIGN.md §7 commits to, exercised on randomized
//! inputs with deterministic, replayable seeds.

use mlmem_spgemm::chunk::partition::{csr_prefix_bytes, is_partition, partition_balanced, range_bytes};
use mlmem_spgemm::chunk::{gpu_chunked_sim, knl_chunked_sim};
use mlmem_spgemm::gen::rhs::{banded, random_csr, uniform_degree};
use mlmem_spgemm::gen::scale::ScaleFactor;
use mlmem_spgemm::kkmem::{spgemm, spgemm_sim, AccKind, Placement, SpgemmOptions};
use mlmem_spgemm::memory::arch::{knl, p100, GpuMode, KnlMode};
use mlmem_spgemm::memory::MemSim;
use mlmem_spgemm::sparse::ops::{spgemm_reference, transpose};
use mlmem_spgemm::sparse::Csr;
use mlmem_spgemm::util::proptest::{check, Gen};

fn gen_csr(g: &mut Gen, max_n: usize) -> Csr {
    let nrows = g.usize(1, max_n);
    let ncols = g.usize(1, max_n);
    let max_deg = g.usize(0, 8.min(ncols));
    random_csr(nrows, ncols, 0, max_deg, g.u64())
}

fn gen_pair(g: &mut Gen, max_n: usize) -> (Csr, Csr) {
    let m = g.usize(1, max_n);
    let k = g.usize(1, max_n);
    let n = g.usize(1, max_n);
    let da = g.usize(0, 6.min(k));
    let db = g.usize(0, 6.min(n));
    (
        random_csr(m, k, 0, da, g.u64()),
        random_csr(k, n, 0, db, g.u64()),
    )
}

#[test]
fn prop_native_spgemm_matches_reference_all_acc_kinds() {
    check("native spgemm == reference", 40, |g| {
        let (a, b) = gen_pair(g, 40);
        let expect = spgemm_reference(&a, &b);
        let acc = *g.pick(&AccKind::ALL);
        let threads = g.usize(1, 6);
        let opts = SpgemmOptions { acc, threads, ..Default::default() };
        let c = spgemm(&a, &b, &opts);
        assert!(c.approx_eq(&expect, 1e-10), "acc {} threads {threads}", acc.name());
        c.validate().unwrap();
    });
}

/// Build a CSR from per-row column sets (already distinct and sorted),
/// with random values.
fn csr_from_cols(rows: &[Vec<u32>], ncols: usize, g: &mut Gen) -> Csr {
    let mut rowmap = vec![0usize; rows.len() + 1];
    let mut entries: Vec<u32> = Vec::new();
    let mut values: Vec<f64> = Vec::new();
    for (i, cols) in rows.iter().enumerate() {
        for &c in cols {
            entries.push(c);
            values.push(g.f64(-2.0, 2.0));
        }
        rowmap[i + 1] = entries.len();
    }
    Csr::new(rows.len(), ncols, rowmap, entries, values)
}

/// An input pair engineered to hit every accumulator regime at once: B
/// mixes clustered runs (dense-clustered rows), scattered rows (hash),
/// and near-empty rows (sort); A mixes empty, tiny, scattered, and
/// heavy rows so adjacent output rows land in different regimes.
fn gen_mixed_regime_pair(g: &mut Gen) -> (Csr, Csr) {
    use std::collections::BTreeSet;
    let ncols = g.usize(256, 1024);
    let nb = g.usize(12, 24);
    let mut brows: Vec<Vec<u32>> = Vec::with_capacity(nb);
    for r in 0..nb {
        let mut cols = BTreeSet::new();
        match r % 3 {
            0 => {
                // A contiguous run over a solid chunk of the column space.
                let len = g.usize(ncols / 4, ncols / 2);
                let start = g.usize(0, ncols - len);
                for j in start..start + len {
                    cols.insert(j as u32);
                }
            }
            1 => {
                for _ in 0..g.usize(4, 12) {
                    cols.insert(g.usize(0, ncols - 1) as u32);
                }
            }
            _ => {
                for _ in 0..g.usize(1, 2) {
                    cols.insert(g.usize(0, ncols - 1) as u32);
                }
            }
        }
        brows.push(cols.into_iter().collect());
    }
    let b = csr_from_cols(&brows, ncols, g);
    let na = g.usize(8, 20);
    let mut arows: Vec<Vec<u32>> = Vec::with_capacity(na);
    for i in 0..na {
        let mut cols = BTreeSet::new();
        let deg = match i % 4 {
            0 => 0,
            1 => g.usize(1, 2),
            2 => g.usize(3, 6),
            _ => g.usize(6, nb.min(12)),
        };
        for _ in 0..deg {
            cols.insert(g.usize(0, nb - 1) as u32);
        }
        arows.push(cols.into_iter().collect());
    }
    let a = csr_from_cols(&arows, nb, g);
    (a, b)
}

#[test]
fn prop_adaptive_bit_identical_to_reference_on_mixed_regimes() {
    // The adaptive dispatcher must not merely approximate the fixed
    // strategies — every accumulator adds each output entry's products
    // in the same k-order, so the result is bit-identical to the
    // sequential reference regardless of which band a row lands in.
    check("adaptive spgemm bit-identical", 25, |g| {
        let (a, b) = gen_mixed_regime_pair(g);
        let expect = spgemm_reference(&a, &b);
        let threads = g.usize(1, 4);
        for acc in [AccKind::Adaptive, AccKind::Hash] {
            let opts = SpgemmOptions { acc, threads, sort_output: true, ..Default::default() };
            let c = spgemm(&a, &b, &opts);
            assert_eq!(c.rowmap, expect.rowmap, "{} threads {threads}", acc.name());
            assert_eq!(c.entries, expect.entries, "{}", acc.name());
            for (x, y) in c.values.iter().zip(&expect.values) {
                assert!(x == y, "{}: {x} != {y}", acc.name());
            }
        }
    });
}

#[test]
fn prop_simulated_spgemm_matches_reference() {
    check("simulated spgemm == reference", 15, |g| {
        let (a, b) = gen_pair(g, 30);
        let expect = spgemm_reference(&a, &b);
        let scale = ScaleFactor::default();
        let arch = if g.bool(0.5) {
            knl(KnlMode::Ddr, 256, scale)
        } else {
            p100(GpuMode::Hbm, scale)
        };
        let mut sim = MemSim::new(arch.spec.clone());
        let prod = spgemm_sim(
            &mut sim,
            &a,
            &b,
            Placement::uniform(arch.default_loc),
            &SpgemmOptions::default(),
        )
        .expect("small problems always fit");
        assert!(prod.c.approx_eq(&expect, 1e-10));
        let rep = sim.finish();
        assert!(rep.seconds >= 0.0 && rep.gflops >= 0.0);
        assert!(rep.l1_miss_pct <= 100.0 && rep.l2_miss_pct <= 100.0);
    });
}

#[test]
fn prop_knl_chunked_equals_unchunked_any_budget() {
    check("knl chunked == reference", 15, |g| {
        let (a, b) = gen_pair(g, 30);
        let expect = spgemm_reference(&a, &b);
        let budget = g.usize(64, (b.size_bytes() as usize).max(65)) as u64;
        let arch = knl(KnlMode::Ddr, 256, ScaleFactor::default());
        let mut sim = MemSim::new(arch.spec);
        let p = knl_chunked_sim(&mut sim, &a, &b, budget, &SpgemmOptions::default())
            .expect("fits DDR");
        assert!(p.c.approx_eq(&expect, 1e-10), "budget {budget}");
    });
}

#[test]
fn prop_gpu_chunked_equals_unchunked_any_budget() {
    check("gpu chunked == reference", 15, |g| {
        let (a, b) = gen_pair(g, 30);
        let expect = spgemm_reference(&a, &b);
        let total = (a.size_bytes() + b.size_bytes()) as usize;
        let budget = g.usize(1024, (2 * total).max(1025)) as u64;
        let arch = p100(GpuMode::Pinned, ScaleFactor::default());
        let mut sim = MemSim::new(arch.spec);
        let p = gpu_chunked_sim(&mut sim, &a, &b, budget, &SpgemmOptions::default())
            .expect("fits host");
        assert!(p.c.approx_eq(&expect, 1e-10), "budget {budget}");
        assert!(p.copied_bytes > 0);
    });
}

#[test]
fn prop_all_engines_produce_identical_sorted_products() {
    use mlmem_spgemm::engine::{Engine, EngineKind, Problem};
    check("all engines agree", 8, |g| {
        let (a, b) = g.csr_pair(30, 5);
        let mut expect = spgemm_reference(&a, &b);
        expect.sort_rows();
        let knl_arch = std::sync::Arc::new(knl(KnlMode::Ddr, 256, ScaleFactor::default()));
        let gpu_arch = std::sync::Arc::new(p100(GpuMode::Pinned, ScaleFactor::default()));
        let budget = (b.size_bytes() / 2).max(512);
        let problem = Problem::new(&a, &b);
        for kind in EngineKind::ALL {
            let arch = if kind == EngineKind::GpuChunk {
                std::sync::Arc::clone(&gpu_arch)
            } else {
                std::sync::Arc::clone(&knl_arch)
            };
            let eng = kind
                .build(arch, SpgemmOptions::default(), Some(budget))
                .expect("engine builds");
            let rep = eng
                .execute(&problem)
                .unwrap_or_else(|e| panic!("{}: {e}", kind.name()));
            let mut c = rep.c;
            c.sort_rows();
            assert_eq!(c.rowmap, expect.rowmap, "{}", kind.name());
            assert_eq!(c.entries, expect.entries, "{}", kind.name());
            assert!(c.approx_eq(&expect, 1e-9), "{}", kind.name());
        }
    });
}

#[test]
fn prop_chain_orders_and_chain_path_agree_with_reference() {
    use mlmem_spgemm::coordinator::{execute, Job, JobKind, PlannerOptions, Policy};
    use std::sync::Arc;
    check("3-chain assoc orders + chain path == reference", 10, |g| {
        // Random compatible 3-chain with non-empty rows so the simulated
        // runs do real work.
        let (m, k, l, n) = (g.usize(2, 25), g.usize(2, 25), g.usize(2, 25), g.usize(2, 25));
        let m1 = random_csr(m, k, 1, 4.min(k), g.u64());
        let m2 = random_csr(k, l, 1, 4.min(l), g.u64());
        let m3 = random_csr(l, n, 1, 4.min(n), g.u64());
        let left = spgemm_reference(&spgemm_reference(&m1, &m2), &m3);
        let right = spgemm_reference(&m1, &spgemm_reference(&m2, &m3));
        // Matrix multiplication is associative up to FP rounding.
        assert!(left.approx_eq(&right, 1e-9), "association orders disagree");

        let arch = Arc::new(knl(KnlMode::Ddr, 64, ScaleFactor::default()));
        let mats = vec![Arc::new(m1), Arc::new(m2), Arc::new(m3)];
        let mut job = Job::new(1, JobKind::Chain { mats }, arch, Policy::Auto);
        job.keep_product = true;
        let r = execute(&job, &PlannerOptions::default()).expect("chain executes");
        let c = r.c.as_ref().expect("chain keeps its product");
        assert!(c.approx_eq(&left, 1e-9), "chain product far from reference");

        // The chain records its total prediction, and the cost model
        // never underestimates the simulated time by more than the
        // documented 4x bound (DESIGN.md §8 — the estimates ignore cache
        // absorption, so they err on the overestimate side).
        let summary = r.chain.as_ref().expect("chain summary");
        assert_eq!(summary.hops.len(), 2);
        let predicted = r.predicted.expect("Auto chains record a prediction");
        assert!(
            predicted.total_seconds() >= r.report.seconds * 0.25,
            "prediction underestimates by more than 4x: {} vs {}",
            predicted.total_seconds(),
            r.report.seconds
        );
        for hop in &summary.hops {
            if let Some(p) = hop.predicted {
                assert!(
                    p.total_seconds() >= hop.report.seconds * 0.25,
                    "hop prediction underestimates by more than 4x: {} vs {}",
                    p.total_seconds(),
                    hop.report.seconds
                );
            }
        }
    });
}

#[test]
fn prop_partition_tiles_and_respects_budget() {
    check("partition invariants", 60, |g| {
        let m = gen_csr(g, 60);
        let prefix = csr_prefix_bytes(&m);
        let total = prefix[m.nrows].max(1);
        let budget = g.usize(16, 2 * total as usize) as u64;
        let parts = partition_balanced(&prefix, budget);
        assert!(is_partition(&parts, m.nrows));
        for &(lo, hi) in &parts {
            // Single oversized rows are allowed their own part.
            if hi - lo > 1 {
                assert!(
                    range_bytes(&prefix, lo, hi) <= budget,
                    "part {lo}..{hi} over budget {budget}"
                );
            }
        }
    });
}

#[test]
fn prop_transpose_involution_and_spgemm_transpose_identity() {
    check("(AB)^T == B^T A^T", 30, |g| {
        let (a, b) = gen_pair(g, 25);
        let ab_t = transpose(&spgemm_reference(&a, &b));
        let bt_at = spgemm_reference(&transpose(&b), &transpose(&a));
        assert!(ab_t.approx_eq(&bt_at, 1e-10));
        let m = gen_csr(g, 25);
        assert!(transpose(&transpose(&m)).approx_eq(&m, 0.0));
    });
}

#[test]
fn prop_matrixmarket_roundtrip() {
    check("matrixmarket roundtrip", 20, |g| {
        let m = gen_csr(g, 30);
        let dir = std::env::temp_dir().join("mlmem_prop_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("m{}.mtx", g.case_seed));
        mlmem_spgemm::sparse::io::write_matrix_market(&m, &path).unwrap();
        let back = mlmem_spgemm::sparse::io::read_matrix_market(&path).unwrap();
        assert!(m.approx_eq(&back, 1e-12));
        let _ = std::fs::remove_file(path);
    });
}

#[test]
fn prop_tricount_matches_naive() {
    check("tricount == naive", 20, |g| {
        let n = g.usize(3, 50);
        let p = g.f64(0.05, 0.4);
        let adj = mlmem_spgemm::gen::graphs::erdos_renyi(n, p, g.u64());
        let expect = mlmem_spgemm::tricount::count::tricount_naive(&adj);
        let l = mlmem_spgemm::tricount::degree_sorted_lower(&adj);
        let lc = mlmem_spgemm::kkmem::CompressedMatrix::compress(&l);
        let threads = g.usize(1, 4);
        assert_eq!(mlmem_spgemm::tricount::tricount(&l, &lc, threads), expect);
    });
}

#[test]
fn prop_gpu_hbm_never_slower_than_pinned() {
    check("HBM >= pinned on GPU", 10, |g| {
        // Irregular inputs with enough work that the model is stable.
        let n = g.usize(100, 300);
        let a = uniform_degree(n, n, g.usize(2, 6), g.u64());
        let b = uniform_degree(n, n, g.usize(2, 6), g.u64());
        let scale = ScaleFactor::default();
        let run = |mode: GpuMode| {
            let arch = p100(mode, scale);
            let mut sim = MemSim::new(arch.spec.clone());
            spgemm_sim(
                &mut sim,
                &a,
                &b,
                Placement::uniform(arch.default_loc),
                &SpgemmOptions::default(),
            )
            .expect("fits");
            sim.finish().gflops
        };
        let hbm = run(GpuMode::Hbm);
        let pin = run(GpuMode::Pinned);
        assert!(hbm >= pin, "HBM {hbm} < pinned {pin}");
    });
}

#[test]
fn prop_banded_products_stay_banded() {
    check("band conv width", 20, |g| {
        let n = g.usize(20, 80);
        let bw1 = g.usize(1, 4);
        let bw2 = g.usize(1, 4);
        let a = banded(n, n, 3, bw1, g.u64());
        let b = banded(n, n, 3, bw2, g.u64());
        let c = spgemm_reference(&a, &b);
        // Band of a product is at most the sum of bands (+ spread slack
        // from the diagonal mapping).
        let max_band = (bw1 + bw2 + 2) as i64;
        for i in 0..c.nrows {
            let (cols, _) = c.row(i);
            for &cc in cols {
                assert!(
                    (cc as i64 - i as i64).abs() <= max_band,
                    "entry ({i},{cc}) outside band {max_band}"
                );
            }
        }
    });
}

#[test]
fn prop_symbolic_sizes_match_numeric() {
    check("symbolic == numeric sizes", 30, |g| {
        let (a, b) = gen_pair(g, 35);
        let comp = mlmem_spgemm::kkmem::CompressedMatrix::compress(&b);
        let sizes = mlmem_spgemm::kkmem::symbolic::symbolic(&a, &comp);
        let c = spgemm_reference(&a, &b);
        for i in 0..c.nrows {
            assert_eq!(sizes[i], c.row_len(i), "row {i}");
        }
    });
}
