//! Fast-pool residency manager integration suite (DESIGN.md §9): the
//! acceptance scenario — a `serve` batch sharing one large operand under
//! a GPU profile stages that operand exactly once (pool hit on jobs
//! 2..N), stays bit-identical to the cache-disabled run, and evicts
//! within capacity when the working set cannot co-reside — plus the
//! KNL serve-path copy-skip.

use mlmem_spgemm::bench::experiments::{serve_lhs, serve_rhs};
use mlmem_spgemm::coordinator::{Decision, JobResult, MetricsSnapshot, Session, SubmitOptions};
use mlmem_spgemm::gen::rhs::uniform_degree;
use mlmem_spgemm::gen::scale::ScaleFactor;
use mlmem_spgemm::memory::arch::{knl, p100, Arch, GpuMode, KnlMode};
use mlmem_spgemm::memory::{Location, FAST, SLOW};
use mlmem_spgemm::prelude::*;
use std::sync::Arc;

/// Heavily shrunk P100: operand sizes derive from the usable fast bytes,
/// so the scenario shape is scale-free while each simulated job stays
/// cheap.
fn gpu_arch() -> Arc<Arch> {
    Arc::new(p100(GpuMode::Pinned, ScaleFactor::new(64 * 1024)))
}

fn fast_usable(arch: &Arch) -> u64 {
    arch.spec.pools[FAST.0].usable()
}

/// `parts_b` of a GPU staging decision (None for flat/DP plans).
fn parts_b(d: &Decision) -> Option<usize> {
    match d {
        Decision::ChunkedGpu { parts_b, .. } | Decision::Pipelined { parts_b, .. } => {
            Some(*parts_b)
        }
        _ => None,
    }
}

#[test]
fn serve_batch_stages_shared_operand_once_and_is_bit_identical() {
    let arch = gpu_arch();
    let usable = fast_usable(&arch);
    let b = Arc::new(serve_rhs(usable, 1));
    let a = Arc::new(serve_lhs(usable, b.nrows, 2));
    // Scenario preconditions: B alone is cacheable in the fast pool, the
    // whole job is not flat-fast-able (C weighs about as much as A).
    assert!(b.size_bytes() < usable, "B must fit the pool alone");
    assert!(
        a.size_bytes() * 2 + b.size_bytes() > usable,
        "A + B + C must exceed the pool"
    );

    let n = 4;
    let run_batch = |cached: bool| -> (Vec<JobResult>, MetricsSnapshot) {
        // Memoization off: this test measures operand staging across
        // genuinely repeated computations; memo hits would skip them.
        let session = Session::builder(Arc::clone(&arch))
            .workers(1)
            .operand_cache(cached)
            .memoize(false)
            .build();
        let ha = session.register(Arc::clone(&a));
        let hb = session.register(Arc::clone(&b));
        let results: Vec<JobResult> = (0..n)
            .map(|_| {
                session
                    .spgemm_with(
                        ha,
                        hb,
                        SubmitOptions { keep_product: true, ..Default::default() },
                    )
                    .expect("admitted")
                    .wait()
                    .expect("job ok")
            })
            .collect();
        (results, session.metrics())
    };
    let (cached, cm) = run_batch(true);
    let (plain, pm) = run_batch(false);

    // Job 1 staged B in one unsplit part (Algorithm 3), so the capture
    // retained a whole copy...
    assert_eq!(parts_b(&cached[0].decision), Some(1), "{:?}", cached[0].decision);
    // ...and jobs 2..N leased it straight from the pool: B crossed the
    // slow->fast link exactly once in the whole batch.
    assert_eq!(cm.residency.hits, (n - 1) as u64);
    assert_eq!(pm.residency.hits, 0, "disabled cache never hits");
    let slow_reads = |r: &JobResult| r.report.traffic[SLOW.0].bulk_read_bytes as i128;
    let delta = slow_reads(&cached[0]) - slow_reads(&cached[1]);
    let b_bytes = b.size_bytes() as i128;
    assert!(
        delta >= b_bytes - 4096 && delta <= b_bytes + 4096,
        "jobs 2..N must skip B's copy-in: delta {delta} vs B {b_bytes}"
    );
    for r in &cached[1..] {
        assert!(
            r.report.seconds < cached[0].report.seconds,
            "pool hit must be strictly faster: {} !< {}",
            r.report.seconds,
            cached[0].report.seconds
        );
        assert_eq!(r.report.seconds, cached[1].report.seconds, "hits are deterministic");
    }

    // The cache-disabled batch replays job 1 every time, and the cached
    // first job (cold pool) matches it exactly.
    assert_eq!(plain[1].report.seconds, plain[0].report.seconds);
    assert_eq!(cached[0].report.seconds, plain[0].report.seconds);
    let cached_total: f64 = cached.iter().map(|r| r.report.seconds).sum();
    let plain_total: f64 = plain.iter().map(|r| r.report.seconds).sum();
    assert!(cached_total < plain_total, "{cached_total} !< {plain_total}");

    // Bit-identical products, job by job.
    for (c, p) in cached.iter().zip(&plain) {
        let cc = c.c.as_ref().expect("keep_product");
        let pc = p.c.as_ref().expect("keep_product");
        assert_eq!(cc.rowmap, pc.rowmap);
        assert_eq!(cc.entries, pc.entries);
        assert!(cc.approx_eq(pc, 0.0), "values must be bit-identical");
    }
}

#[test]
fn eviction_keeps_accounting_within_capacity() {
    let arch = gpu_arch();
    let usable = fast_usable(&arch);
    let b0 = Arc::new(serve_rhs(usable, 11));
    let b1 = Arc::new(serve_rhs(usable, 12));
    let a0 = Arc::new(serve_lhs(usable, b0.nrows, 13));
    let a1 = Arc::new(serve_lhs(usable, b1.nrows, 14));
    assert!(
        b0.size_bytes() + b1.size_bytes() > usable,
        "the two RHSs must not co-reside"
    );

    // Memoization off: the repeated (a0, b0) jobs below must recompute
    // to exercise the pool's capture/eviction accounting.
    let session = Session::builder(Arc::clone(&arch)).workers(1).memoize(false).build();
    let ha0 = session.register(a0);
    let hb0 = session.register(Arc::clone(&b0));
    let ha1 = session.register(a1);
    let hb1 = session.register(Arc::clone(&b1));

    session.spgemm(ha0, hb0).unwrap().wait().expect("job 1");
    session.spgemm(ha0, hb0).unwrap().wait().expect("job 2");
    assert_eq!(session.residency(hb0), Some(Location::Pool(FAST)));
    // Capturing B1 must evict B0 (unleased by then) — and vice versa.
    session.spgemm(ha1, hb1).unwrap().wait().expect("job 3");
    assert_eq!(session.residency(hb0), None, "B0 evicted for B1");
    assert_eq!(session.residency(hb1), Some(Location::Pool(FAST)));
    session.spgemm(ha0, hb0).unwrap().wait().expect("job 4");

    let m = session.metrics();
    assert_eq!(m.residency.hits, 1, "only job 2 found its RHS resident");
    assert_eq!(m.residency.evictions, 2);
    assert_eq!(m.residency.evicted_bytes, b0.size_bytes() + b1.size_bytes());
    assert!(m.residency.resident_bytes <= usable);
}

#[test]
fn knl_second_job_skips_the_bulk_copy_in() {
    let arch = Arc::new(knl(KnlMode::Ddr, 64, ScaleFactor::new(64 * 1024)));
    let usable = fast_usable(&arch);
    // B at half the MCDRAM pool: an explicit Chunked policy with the full
    // budget stages it in exactly one part, which the pool captures.
    let b_rows = (usable as usize / 2) / 104;
    let b = Arc::new(uniform_degree(b_rows, b_rows, 8, 5));
    let a = Arc::new(uniform_degree(400, b_rows, 4, 6));
    assert!(b.size_bytes() < usable);

    let session = Session::builder(Arc::clone(&arch)).workers(1).build();
    let ha = session.register(a);
    let hb = session.register(Arc::clone(&b));
    let submit = || SubmitOptions {
        policy: Some(Policy::Chunked { fast_budget: usable }),
        ..Default::default()
    };
    let r1 = session.spgemm_with(ha, hb, submit()).unwrap().wait().expect("job 1");
    assert!(
        matches!(r1.decision, Decision::ChunkedKnl { parts: 1 }),
        "{:?}",
        r1.decision
    );
    // Algorithm 1 stages exactly B; the staged bytes are its copy-in.
    assert_eq!(r1.report.traffic[SLOW.0].bulk_read_bytes, b.size_bytes());

    let r2 = session.spgemm_with(ha, hb, submit()).unwrap().wait().expect("job 2");
    // The resident run consumes B in place: no staging traffic at all,
    // strictly less simulated time, and the hit is counted.
    assert_eq!(r2.report.traffic[SLOW.0].bulk_read_bytes, 0);
    assert!(r2.report.seconds < r1.report.seconds);
    assert_eq!(session.metrics().residency.hits, 1);
    assert_eq!(r2.c_nnz, r1.c_nnz);
}
