//! Integration: the python-AOT → rust-PJRT round trip. Requires
//! `make artifacts` to have produced `artifacts/` (skips politely
//! otherwise so `cargo test` works on a fresh clone).

use mlmem_spgemm::runtime::{spgemm_via_blocks, BlockExecutor};
use mlmem_spgemm::sparse::ops::spgemm_reference;

fn executor() -> Option<BlockExecutor> {
    let dir = BlockExecutor::default_dir();
    if !BlockExecutor::artifacts_present(&dir) {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(BlockExecutor::load(&dir).expect("artifacts present but failed to load"))
}

#[test]
fn aot_matmul_matches_native() {
    let Some(exe) = executor() else { return };
    let m = exe.meta;
    let mut rng = mlmem_spgemm::util::rng::Xoshiro256::seed_from_u64(42);
    let a: Vec<f32> = (0..m.m * m.k).map(|_| rng.f64_range(-1.0, 1.0) as f32).collect();
    let b: Vec<f32> = (0..m.k * m.n).map(|_| rng.f64_range(-1.0, 1.0) as f32).collect();
    let c = exe.matmul(&a, &b).expect("execute");
    // Spot-check a handful of entries against a scalar dot product.
    for &(i, j) in &[(0usize, 0usize), (1, 5), (37, 200), (m.m - 1, m.n - 1)] {
        let expect: f32 = (0..m.k).map(|kk| a[i * m.k + kk] * b[kk * m.n + j]).sum();
        let got = c[i * m.n + j];
        assert!(
            (got - expect).abs() <= 1e-3 * expect.abs().max(1.0),
            "C[{i},{j}] = {got}, expect {expect}"
        );
    }
}

#[test]
fn aot_fused_adds_prev() {
    let Some(exe) = executor() else { return };
    let m = exe.meta;
    let a = vec![0.0f32; m.m * m.k];
    let b = vec![0.0f32; m.k * m.n];
    let c_prev: Vec<f32> = (0..m.m * m.n).map(|i| i as f32 * 0.25).collect();
    let c = exe.matmul_fused(&a, &b, &c_prev).expect("execute");
    assert_eq!(c, c_prev, "0 @ 0 + C must be C");
}

#[test]
fn block_spgemm_matches_scalar_path() {
    let Some(exe) = executor() else { return };
    // A sparse product executed entirely through the dense-block AOT
    // path must equal the KKMEM scalar result.
    let a = mlmem_spgemm::gen::rhs::banded(300, 300, 6, 8, 1);
    let b = mlmem_spgemm::gen::rhs::banded(300, 300, 6, 8, 2);
    let via_blocks = spgemm_via_blocks(&exe, &a, &b).expect("block path");
    let reference = spgemm_reference(&a, &b);
    assert!(
        via_blocks.approx_eq(&reference, 1e-3),
        "dense-block product diverges from reference"
    );
}

#[test]
fn executor_reports_platform() {
    let Some(exe) = executor() else { return };
    assert_eq!(exe.platform(), "cpu");
}
