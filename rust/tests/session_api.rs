//! Session-handle API integration suite: one `Session` serving a mixed
//! batch — spgemm + tricount against shared registered operands, a
//! cancelled job, an SLO rejection at admission, a mid-run deadline
//! expiry, and a backpressure rejection — with typed `MlmemError`s for
//! every failure and bit-identical products to the direct
//! `coordinator::execute` path for the successes. Plus the
//! admission-control recovery and operand-registry reuse satellites.

use mlmem_spgemm::coordinator::{
    execute, Job, JobKind, PlannerOptions, Session, SubmitOptions,
};
use mlmem_spgemm::engine::EngineKind;
use mlmem_spgemm::error::JobControl;
use mlmem_spgemm::gen::scale::ScaleFactor;
use mlmem_spgemm::kkmem::{CompressedMatrix, SpgemmOptions};
use mlmem_spgemm::memory::arch::{knl, Arch, KnlMode};
use mlmem_spgemm::prelude::*;
use std::sync::Arc;
use std::time::Duration;

fn arch() -> Arc<Arch> {
    Arc::new(knl(KnlMode::Ddr, 64, ScaleFactor::default()))
}

/// Big enough that a simulated run takes real milliseconds — the
/// backpressure submissions below race against it with microseconds.
fn operand(seed: u64) -> Arc<Csr> {
    Arc::new(mlmem_spgemm::gen::rhs::random_csr(200, 200, 1, 6, seed))
}

#[test]
fn mixed_batch_typed_failures_and_bit_identical_successes() {
    let arch = arch();
    // Result memoization off: this suite pins the recompute path —
    // with it on, the repeat submissions below would coalesce or replay
    // instead of hitting backpressure.
    let session = Session::builder(Arc::clone(&arch))
        .workers(1)
        .max_pending(2)
        .memoize(false)
        .build();
    let a_mat = operand(1);
    let b_mat = operand(2);
    let adj_mat = Arc::new(mlmem_spgemm::gen::graphs::erdos_renyi(60, 0.2, 3));
    let a = session.register(Arc::clone(&a_mat));
    let b = session.register(Arc::clone(&b_mat));
    let adj = session.register(Arc::clone(&adj_mat));

    // Two successes share the registered operands and fill the queue...
    let h_mul = session
        .spgemm_with(a, b, SubmitOptions { keep_product: true, ..Default::default() })
        .expect("first job admitted");
    let h_tri = session.tricount(adj).expect("second job admitted");
    // ...so the next submission is a deterministic backpressure
    // rejection while the single worker grinds the first job.
    let err = match session.spgemm(a, b) {
        Err(e) => e,
        Ok(_) => panic!("expected backpressure rejection"),
    };
    assert!(matches!(
        err,
        MlmemError::AdmissionRejected {
            pending: 2,
            max_pending: 2,
            priced_seconds: None,
            ..
        }
    ));

    // One pre-cancelled job, observed at the worker's first checkpoint —
    // and an already-expired deadline that SLO-aware admission now turns
    // away up front with the priced context, instead of letting it burn
    // the worker and expire mid-run.
    session.drain();
    let cancel = JobControl::new();
    cancel.cancel();
    let h_cancelled = session
        .spgemm_with(a, b, SubmitOptions { control: Some(cancel), ..Default::default() })
        .expect("admitted after drain");
    let err = session
        .spgemm_with(
            a,
            b,
            SubmitOptions { deadline: Some(Duration::ZERO), ..Default::default() },
        )
        .expect_err("a zero simulated-seconds budget cannot be met");
    assert!(matches!(
        err,
        MlmemError::AdmissionRejected {
            priced_seconds: Some(_),
            deadline_seconds: Some(_),
            ..
        }
    ));
    assert!(matches!(h_cancelled.wait(), Err(MlmemError::Cancelled)));

    // Successes: the spgemm product is bit-identical to the direct
    // (session-less) execute path on the same operands.
    let r_mul = h_mul.wait().expect("spgemm succeeds");
    let c_session = r_mul.c.as_ref().expect("keep_product attaches C");
    let mut job = Job::new(
        99,
        JobKind::Spgemm { a: Arc::clone(&a_mat), b: Arc::clone(&b_mat) },
        Arc::clone(&arch),
        Policy::Auto,
    );
    job.keep_product = true;
    let r_direct = execute(&job, &PlannerOptions::default()).expect("direct path succeeds");
    let c_direct = r_direct.c.as_ref().expect("direct path keeps C");
    assert_eq!(r_mul.decision, r_direct.decision);
    assert_eq!(c_session.rowmap, c_direct.rowmap);
    assert_eq!(c_session.entries, c_direct.entries);
    assert!(c_session.approx_eq(c_direct, 0.0), "values must be bit-identical");

    // The tricount success drains through the non-blocking poll and
    // matches the reference count.
    let mut h_tri = h_tri;
    let mut out = None;
    for _ in 0..10_000 {
        out = h_tri.try_wait();
        if out.is_some() {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    let r_tri = out.expect("tricount finishes").expect("tricount succeeds");
    let l = mlmem_spgemm::tricount::degree_sorted_lower(&adj_mat);
    let lc = CompressedMatrix::compress(&l);
    let expect = mlmem_spgemm::tricount::tricount(&l, &lc, 2);
    assert_eq!(r_tri.triangles, Some(expect));

    session.drain();
    let m = session.metrics();
    assert_eq!(m.completed, 2);
    assert_eq!(m.cancelled, 1);
    assert_eq!(m.rejected, 2, "backpressure + SLO rejection");
    assert_eq!(m.failed, 0);
    assert_eq!(m.queue_depth, 0);
}

#[test]
fn admission_control_rejects_beyond_max_pending_and_recovers() {
    // Memoization off: identical submissions must queue (and overflow),
    // not coalesce onto the in-flight run.
    let session = Session::builder(arch()).workers(1).max_pending(1).memoize(false).build();
    let a = session.register(operand(10));
    let b = session.register(operand(11));

    let mut accepted = Vec::new();
    let mut rejected = 0u64;
    for _ in 0..10 {
        match session.spgemm(a, b) {
            Ok(h) => accepted.push(h),
            Err(e) => {
                assert!(matches!(e, MlmemError::AdmissionRejected { .. }), "{e}");
                rejected += 1;
            }
        }
    }
    assert!(rejected > 0, "a tight loop must outrun a 1-deep queue");
    assert_eq!(session.metrics().rejected, rejected);

    // A drained queue accepts again, and the new job completes.
    session.drain();
    let h = session.spgemm(a, b).expect("drained queue admits");
    assert!(h.wait().is_ok());
    let m = session.metrics();
    assert_eq!(m.completed, accepted.len() as u64 + 1);
    assert_eq!(m.rejected, rejected);
    for h in accepted {
        assert!(h.wait().is_ok());
    }
}

#[test]
fn registry_reuse_skips_second_symbolic_pass() {
    // Memoization off so the second multiply actually runs — this test
    // pins the pair cache (symbolic reuse), not the result cache.
    let session = Session::builder(arch()).workers(1).memoize(false).build();
    let a = session.register(operand(20));
    let b = session.register(operand(21));

    let first = session.spgemm(a, b).unwrap().wait().expect("ok");
    assert_eq!(session.symbolic_passes(), 1);

    // Second multiply against the same registered pair: no second pass.
    let second = session.spgemm(a, b).unwrap().wait().expect("ok");
    assert_eq!(session.symbolic_passes(), 1);
    assert_eq!(second.c_nnz, first.c_nnz);
    assert_eq!(second.decision, first.decision);

    // A new pair pays its own (single) pass...
    session.spgemm(b, a).unwrap().wait().expect("ok");
    assert_eq!(session.symbolic_passes(), 2);

    // ...and the synchronous engine path rides the same cache.
    let (_, rep) = session
        .execute_engine(EngineKind::Sim, a, b, SpgemmOptions::default(), None)
        .expect("engine path ok");
    assert_eq!(rep.c.nnz(), first.c_nnz);
    assert_eq!(session.symbolic_passes(), 2);
}

#[test]
fn deadline_expires_mid_run_at_a_chunk_boundary() {
    // A chunked policy with a tiny budget forces many passes over a
    // problem whose simulated run takes far longer than the deadline
    // (the simulator pushes every access of every pass through the
    // cache hierarchy), so the deadline reliably expires while passes
    // remain — observed at the next chunk boundary (or the worker's
    // first checkpoint on a loaded machine; either way the typed error
    // is DeadlineExceeded).
    let session = Session::builder(arch()).workers(1).build();
    let a = session.register(Arc::new(mlmem_spgemm::gen::rhs::random_csr(600, 600, 6, 10, 30)));
    let b = session.register(Arc::new(mlmem_spgemm::gen::rhs::random_csr(600, 600, 6, 10, 31)));
    let budget = session.operand(b).unwrap().size_bytes() / 8;
    let h = session
        .spgemm_with(
            a,
            b,
            SubmitOptions {
                policy: Some(Policy::Chunked { fast_budget: budget }),
                deadline: Some(Duration::from_millis(2)),
                ..Default::default()
            },
        )
        .unwrap();
    assert!(matches!(h.wait(), Err(MlmemError::DeadlineExceeded)));
    assert_eq!(session.metrics().cancelled, 1);
}
