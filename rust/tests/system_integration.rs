//! System-level integration tests: the paper's qualitative claims end to
//! end (the same assertions EXPERIMENTS.md reports), coordinator failure
//! injection, and the CLI binary itself.

use mlmem_spgemm::bench::experiments::{
    run_gpu, run_gpu_chunk, run_knl, run_knl_dp, Mul, ProblemCache,
};
use mlmem_spgemm::coordinator::{Session, SubmitOptions};
use mlmem_spgemm::gen::scale::ScaleFactor;
use mlmem_spgemm::memory::arch::{knl, p100, GpuMode, KnlMode};
use mlmem_spgemm::prelude::*;
use std::sync::Arc;

fn problems() -> (ProblemCache, ScaleFactor) {
    (ProblemCache::default(), ScaleFactor::default())
}

/// Paper claim (Figures 3/4): at 64 threads KKMEM is not bandwidth
/// bound on DDR; with hyperthreads the Laplace R×A gap opens.
#[test]
fn claim_knl_gap_opens_with_hyperthreads() {
    let (mut cache, s) = problems();
    let p = cache.get(Domain::Laplace3D, 2.0, s).clone();
    let (a, b) = Mul::RxA.operands(&p);
    let ddr64 = run_knl(a, b, KnlMode::Ddr, 64, s).unwrap();
    let hbm64 = run_knl(a, b, KnlMode::Hbm, 64, s).unwrap();
    let ddr256 = run_knl(a, b, KnlMode::Ddr, 256, s).unwrap();
    let hbm256 = run_knl(a, b, KnlMode::Hbm, 256, s).unwrap();
    assert!(
        (hbm64.gflops - ddr64.gflops).abs() / hbm64.gflops < 0.05,
        "64T should be compute-bound: HBM {} vs DDR {}",
        hbm64.gflops,
        ddr64.gflops
    );
    assert!(
        hbm256.gflops > 1.15 * ddr256.gflops,
        "256T gap expected: HBM {} vs DDR {}",
        hbm256.gflops,
        ddr256.gflops
    );
}

/// Paper claim (§3.2): the DDR/HBM gap shrinks with operand density.
#[test]
fn claim_gap_shrinks_with_density() {
    let (mut cache, s) = problems();
    let mut gap = |d: Domain| {
        let p = cache.get(d, 2.0, s).clone();
        let (a, b) = Mul::RxA.operands(&p);
        let ddr = run_knl(a, b, KnlMode::Ddr, 256, s).unwrap();
        let hbm = run_knl(a, b, KnlMode::Hbm, 256, s).unwrap();
        hbm.gflops / ddr.gflops
    };
    let laplace = gap(Domain::Laplace3D);
    let elasticity = gap(Domain::Elasticity);
    assert!(
        laplace > elasticity,
        "Laplace gap {laplace:.2} should exceed Elasticity gap {elasticity:.2}"
    );
    assert!(elasticity < 1.1, "dense RxA should be compute-bound, gap {elasticity:.2}");
}

/// Paper claim (Figures 3/4): cache mode recovers HBM performance.
#[test]
fn claim_cache_mode_recovers_hbm() {
    let (mut cache, s) = problems();
    let p = cache.get(Domain::Laplace3D, 2.0, s).clone();
    let (a, b) = Mul::RxA.operands(&p);
    let hbm = run_knl(a, b, KnlMode::Hbm, 256, s).unwrap();
    let c16 = run_knl(a, b, KnlMode::Cache16, 256, s).unwrap();
    assert!(
        c16.gflops > 0.9 * hbm.gflops,
        "Cache16 {} should approach HBM {}",
        c16.gflops,
        hbm.gflops
    );
}

/// Paper claim (Figures 9/10): DP recovers most of the DDR drop when B
/// fits fast memory.
#[test]
fn claim_dp_recovers_ddr_drop() {
    let (mut cache, s) = problems();
    let p = cache.get(Domain::Laplace3D, 2.0, s).clone();
    let (a, b) = Mul::RxA.operands(&p);
    let ddr = run_knl(a, b, KnlMode::Ddr, 256, s).unwrap();
    let dp = run_knl_dp(a, b, 256, s).unwrap();
    let hbm = run_knl(a, b, KnlMode::Hbm, 256, s).unwrap();
    assert!(dp.gflops >= ddr.gflops, "DP {} < DDR {}", dp.gflops, ddr.gflops);
    assert!(dp.gflops > 0.9 * hbm.gflops, "DP {} vs HBM {}", dp.gflops, hbm.gflops);
}

/// Paper claim (Table 3 / §3.3): pinned memory collapses GPU SpGEMM and
/// chunking wins big factors back.
#[test]
fn claim_gpu_chunking_beats_pinned() {
    let (mut cache, s) = problems();
    let p = cache.get(Domain::Brick3D, 4.0, s).clone();
    let (a, b) = Mul::RxA.operands(&p);
    let hbm = run_gpu(a, b, GpuMode::Hbm, s).unwrap();
    let pin = run_gpu(a, b, GpuMode::Pinned, s).unwrap();
    assert!(hbm.gflops > 7.0 * pin.gflops, "HBM {} vs pinned {}", hbm.gflops, pin.gflops);
    let (_, chunk) = run_gpu_chunk(a, b, 16.0, s).unwrap();
    assert!(
        chunk.gflops > 3.0 * pin.gflops,
        "Chunk16 {} should beat pinned {} by a large factor",
        chunk.gflops,
        pin.gflops
    );
    assert!(chunk.gflops < hbm.gflops, "copies must cost something");
}

/// Paper claim (§3.3): UVM sits between HBM and pinned while the problem
/// fits device memory.
#[test]
fn claim_uvm_between_hbm_and_pinned() {
    let (mut cache, s) = problems();
    let p = cache.get(Domain::Brick3D, 4.0, s).clone();
    let (a, b) = Mul::AxP.operands(&p);
    let hbm = run_gpu(a, b, GpuMode::Hbm, s).unwrap().gflops;
    let uvm = run_gpu(a, b, GpuMode::Uvm, s).unwrap().gflops;
    let pin = run_gpu(a, b, GpuMode::Pinned, s).unwrap().gflops;
    assert!(pin < uvm && uvm < hbm, "expected pinned {pin} < UVM {uvm} < HBM {hbm}");
}

/// Failure injection: jobs whose structures cannot fit any pool fail
/// cleanly through the session (no panic, typed error, metrics updated).
#[test]
fn session_reports_failed_jobs() {
    // A tiny scaled machine (DDR ~ 1.5 MiB usable) and a matrix far
    // bigger than that.
    let scale = ScaleFactor::new(64 * 1024);
    let arch = Arc::new(knl(KnlMode::Ddr, 64, scale));
    let a = Arc::new(mlmem_spgemm::gen::rhs::uniform_degree(3000, 3000, 16, 1));
    // A alone is ~600 KiB; A + B + C exceed the ~1.4 MiB usable DDR.
    assert!(a.size_bytes() > 512 * 1024);
    let session = Session::builder(arch).workers(1).max_pending(8).build();
    let ha = session.register(a);
    let h = session
        .spgemm_with(ha, ha, SubmitOptions { policy: Some(Policy::Flat), ..Default::default() })
        .unwrap();
    let err = match h.wait() {
        Ok(_) => panic!("job must fail"),
        Err(e) => e,
    };
    assert!(matches!(err, MlmemError::Alloc(_)), "{err}");
    assert!(err.to_string().contains("does not fit"));
    let m = session.metrics();
    assert_eq!((m.completed, m.failed), (0, 1));
}

/// The GPU planner handles a mixed batch without loss.
#[test]
fn session_mixed_gpu_batch() {
    let s = ScaleFactor::default();
    let arch = Arc::new(p100(GpuMode::Pinned, s));
    let session = Session::builder(arch).workers(2).max_pending(32).build();
    let mut handles = Vec::new();
    for seed in 0..6 {
        let a = session.register(Arc::new(mlmem_spgemm::gen::rhs::random_csr(80, 80, 1, 5, seed)));
        let b = session
            .register(Arc::new(mlmem_spgemm::gen::rhs::random_csr(80, 80, 1, 5, seed + 10)));
        handles.push(session.spgemm(a, b).unwrap());
    }
    for h in handles {
        let r = h.wait().expect("ok");
        assert!(r.report.gflops > 0.0);
    }
}

/// The CLI binary runs an experiment end to end.
#[test]
fn cli_bench_quick_runs() {
    let exe = env!("CARGO_BIN_EXE_mlmem");
    let out = std::process::Command::new(exe)
        .args(["bench", "--exp", "table1,profiles", "--quick", "--out-dir", ""])
        .output()
        .expect("spawn mlmem");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("Table 1"));
    assert!(stdout.contains("MCDRAM"));
}

/// The CLI rejects unknown flags with usage help.
#[test]
fn cli_rejects_unknown() {
    let exe = env!("CARGO_BIN_EXE_mlmem");
    let out = std::process::Command::new(exe)
        .args(["bench", "--bogus", "1"])
        .output()
        .expect("spawn mlmem");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown flag"));
}
