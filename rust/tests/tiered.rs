//! Three-tier out-of-core integration suite (DESIGN.md §14). The
//! contract under test: tiering changes where bytes wait, never what the
//! kernel computes — a three-tier run is bit-identical to the two-tier
//! chunk driver at the same fast cut (and any interleaving of disk-bound
//! jobs through the shared link is bit-identical to serial execution);
//! operands the slow pool cannot hold complete on an `_ooc` profile but
//! fail with a typed `Alloc` error on a two-tier machine; and pipelining
//! both staging boundaries beats serial staging without perturbing a
//! single bit of the product.

use mlmem_spgemm::chunk::{knl_chunked_sim, tiered_sim};
use mlmem_spgemm::coordinator::{
    execute, Decision, Job, JobKind, PlannerOptions, Policy, Session, SubmitOptions,
};
use mlmem_spgemm::engine::{OperandTier, TierAssign};
use mlmem_spgemm::error::MlmemError;
use mlmem_spgemm::gen::rhs::uniform_degree;
use mlmem_spgemm::gen::scale::ScaleFactor;
use mlmem_spgemm::kkmem::SpgemmOptions;
use mlmem_spgemm::memory::arch::{knl, knl_ooc, KnlMode};
use mlmem_spgemm::memory::pool::SLOW;
use mlmem_spgemm::memory::MemSim;
use mlmem_spgemm::sparse::csr::Csr;
use mlmem_spgemm::sparse::ops::spgemm_reference;
use mlmem_spgemm::util::proptest::{check, Gen};
use std::sync::Arc;

/// Bytes of a degree-8 uniform row (8 B rowmap slot + 8 × 12 B entries).
const ROW_BYTES: u64 = 8 + 12 * 8;

#[test]
fn three_tier_bit_identical_to_two_tier_across_generators() {
    check("tiered runs reproduce the two-tier product bitwise", 8, |g: &mut Gen| {
        let (a, b) = g.csr_pair(40, 4);
        let fast_budget = (b.size_bytes() / 4).max(64);
        let slow_budget = (b.size_bytes() / 2).max(128);
        // Two-tier reference at the same fast cut.
        let mut two_sim = MemSim::new(knl(KnlMode::Ddr, 64, ScaleFactor::default()).spec);
        let two =
            knl_chunked_sim(&mut two_sim, &a, &b, fast_budget, &SpgemmOptions::default())
                .expect("two-tier reference");
        let tier = match g.usize(0, 2) {
            0 => TierAssign { a: OperandTier::Mem, b: OperandTier::Disk },
            1 => TierAssign { a: OperandTier::Disk, b: OperandTier::Mem },
            _ => TierAssign { a: OperandTier::Disk, b: OperandTier::Disk },
        };
        let pipelined = g.usize(0, 1) == 1;
        let mut sim = MemSim::new(knl_ooc(KnlMode::Ddr, 64, ScaleFactor::default()).spec);
        let p = tiered_sim(
            &mut sim,
            &a,
            &b,
            slow_budget,
            fast_budget,
            &SpgemmOptions::default(),
            pipelined,
            tier,
        )
        .expect("tiered run");
        assert_eq!(p.n_parts_b, two.n_parts_b, "{tier:?} pipelined={pipelined}");
        assert_eq!(p.c.rowmap, two.c.rowmap, "{tier:?} pipelined={pipelined}");
        assert_eq!(p.c.entries, two.c.entries, "{tier:?} pipelined={pipelined}");
        assert!(
            p.c.approx_eq(&two.c, 0.0),
            "{tier:?} pipelined={pipelined}: values must be bit-identical"
        );
    });
}

#[test]
fn oversized_operand_completes_on_ooc_and_allocs_on_two_tier() {
    // Shrink hard enough that a CI-sized B overflows the slow pool: at
    // 2^20 the KNL DDR arena is a few hundred kilobytes.
    let scale = ScaleFactor::new(1 << 20);
    let two = Arc::new(knl(KnlMode::Ddr, 64, scale));
    let ooc = Arc::new(knl_ooc(KnlMode::Ddr, 64, scale));
    let slow_usable = two.spec.pools[SLOW.0].usable();
    let rows = (slow_usable * 3 / 2 / ROW_BYTES) as usize;
    let b = Arc::new(uniform_degree(rows, rows, 8, 3));
    assert!(b.size_bytes() > slow_usable, "B must overflow the slow pool");
    let a = Arc::new(uniform_degree(128, rows, 2, 4));
    let mk_job = |arch| {
        let kind = JobKind::Spgemm { a: Arc::clone(&a), b: Arc::clone(&b) };
        let mut job = Job::new(1, kind, arch, Policy::Auto);
        job.keep_product = true;
        job
    };
    // Two memory levels: no plan can even hold B, and the failure is the
    // typed allocation error, not a panic or a silent wrong answer.
    let err = execute(&mk_job(two), &PlannerOptions::default())
        .expect_err("a two-tier machine cannot hold B");
    assert!(matches!(err, MlmemError::Alloc(_)), "expected Alloc, got {err:?}");
    // Three levels: capacity forces the tiered enumeration and the job
    // completes with the right product.
    let r = execute(&mk_job(ooc), &PlannerOptions::default()).expect("ooc profile completes");
    assert!(matches!(r.decision, Decision::Tiered { .. }), "got {:?}", r.decision);
    let c = r.c.expect("kept product");
    let expect = spgemm_reference(&a, &b);
    assert_eq!(c.nnz(), expect.nnz());
    assert!(c.approx_eq(&expect, 1e-12));
}

#[test]
fn pipelined_tiered_beats_serial_across_budget_splits() {
    // Dense-ish A gives the inner kernel real compute to hide both
    // staging boundaries behind; the budget splits force several outer
    // groups and many inner chunks.
    let a = uniform_degree(800, 8000, 24, 5);
    let b = uniform_degree(8000, 800, 8, 6);
    let tier = TierAssign { a: OperandTier::Mem, b: OperandTier::Disk };
    let opts = SpgemmOptions::default();
    for (fast_div, slow_div) in [(6, 2), (10, 3)] {
        let fast_budget = b.size_bytes() / fast_div;
        let slow_budget = b.size_bytes() / slow_div;
        let mut serial_sim = MemSim::new(knl_ooc(KnlMode::Ddr, 256, ScaleFactor::default()).spec);
        let serial =
            tiered_sim(&mut serial_sim, &a, &b, slow_budget, fast_budget, &opts, false, tier)
                .expect("serial tiered");
        let serial_rep = serial_sim.finish();
        let mut pipe_sim = MemSim::new(knl_ooc(KnlMode::Ddr, 256, ScaleFactor::default()).spec);
        let piped =
            tiered_sim(&mut pipe_sim, &a, &b, slow_budget, fast_budget, &opts, true, tier)
                .expect("pipelined tiered");
        let pipe_rep = pipe_sim.finish();
        assert!(serial.n_parts_ac >= 2, "split 1/{fast_div},1/{slow_div}: want >1 outer group");
        assert!(
            piped.c.approx_eq(&serial.c, 0.0),
            "split 1/{fast_div},1/{slow_div}: overlap must not perturb the product"
        );
        assert!(
            pipe_rep.seconds < serial_rep.seconds,
            "split 1/{fast_div},1/{slow_div}: pipelined {} !< serial {}",
            pipe_rep.seconds,
            serial_rep.seconds
        );
    }
}

#[test]
fn concurrent_disk_bound_jobs_bit_identical_over_shared_link() {
    // Three jobs whose B overflows the (shrunk) slow pool — every one is
    // capacity-forced through the disk tier and the shared link. Any
    // interleaving of their transfers must yield the serial products.
    let arch = Arc::new(knl_ooc(KnlMode::Ddr, 64, ScaleFactor::new(1024 * 64)));
    let slow_usable = arch.spec.pools[SLOW.0].usable();
    let rows = (slow_usable * 13 / 10 / ROW_BYTES) as usize;
    let pairs: Vec<(Arc<Csr>, Arc<Csr>)> = (0..3u64)
        .map(|i| {
            let b = Arc::new(uniform_degree(rows, rows, 8, 40 + i));
            let a = Arc::new(uniform_degree(192, rows, 2, 50 + i));
            (a, b)
        })
        .collect();
    let submit = || SubmitOptions {
        keep_product: true,
        price_admission: true,
        ..Default::default()
    };
    // Serial reference: one worker, one job in flight at a time.
    let serial = Session::builder(Arc::clone(&arch))
        .workers(1)
        .co_schedule(false)
        .build();
    let mut reference = Vec::new();
    for (a, b) in &pairs {
        let ha = serial.register(Arc::clone(a));
        let hb = serial.register(Arc::clone(b));
        let r = serial.spgemm_with(ha, hb, submit()).unwrap().wait().unwrap();
        assert!(
            matches!(r.decision, Decision::Tiered { .. }),
            "capacity must force tiering, got {:?}",
            r.decision
        );
        reference.push(r.c.expect("kept product"));
    }
    // Concurrent: everything in flight at once, all priced through the
    // shared link, co-scheduler free to reorder.
    let concurrent = Session::builder(arch).workers(4).build();
    let handles: Vec<_> = pairs
        .iter()
        .map(|(a, b)| {
            let ha = concurrent.register(Arc::clone(a));
            let hb = concurrent.register(Arc::clone(b));
            concurrent.spgemm_with(ha, hb, submit()).unwrap()
        })
        .collect();
    for (h, want) in handles.into_iter().zip(&reference) {
        let r = h.wait().unwrap();
        assert!(matches!(r.decision, Decision::Tiered { .. }));
        let got = r.c.expect("kept product");
        assert_eq!(got.rowmap, want.rowmap);
        assert_eq!(got.entries, want.entries);
        assert!(got.approx_eq(want, 0.0), "values must be bit-identical");
    }
}
