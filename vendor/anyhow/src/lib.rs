//! Minimal offline stand-in for the `anyhow` crate, providing the subset
//! this workspace uses: [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros. Semantics match
//! `anyhow` where they overlap: `Error` is a cheap opaque error value that
//! any `std::error::Error` converts into via `?`, and context layers
//! render as a "Caused by" chain in `{:?}`.

use std::fmt;

/// Opaque error type: a message plus an optional chain of causes.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), cause: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: context.to_string(), cause: Some(Box::new(self)) }
    }

    /// Iterate the chain messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.cause.as_deref();
        }
        out.into_iter()
    }

    /// The outermost (most recently attached) message.
    pub fn root_message(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.cause.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.cause.as_deref();
        }
        Ok(())
    }
}

// Like real `anyhow`, `Error` deliberately does NOT implement
// `std::error::Error`; that is what makes this blanket conversion
// coherent (and lets `?` convert any std error into `Error`).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = Vec::new();
        chain.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in chain.into_iter().rev() {
            err = Some(Error { msg, cause: err.map(Box::new) });
        }
        err.expect("chain nonempty")
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let n: u32 = s.parse().context("parsing number")?;
        ensure!(n < 100, "number {n} too large");
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse("42").unwrap(), 42);
        let e = parse("nope").unwrap_err();
        assert_eq!(e.to_string(), "parsing number");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn ensure_and_bail() {
        let e = parse("200").unwrap_err();
        assert_eq!(e.to_string(), "number 200 too large");
        fn fails() -> Result<()> {
            bail!("boom {}", 7);
        }
        assert_eq!(fails().unwrap_err().to_string(), "boom 7");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: std::result::Result<u32, std::num::ParseIntError> = "5".parse();
        let got = ok.with_context(|| "never rendered").unwrap();
        assert_eq!(got, 5);
    }

    #[test]
    fn chain_preserves_order() {
        let e = Error::msg("inner").context("mid").context("outer");
        let msgs: Vec<&str> = e.chain().collect();
        assert_eq!(msgs, vec!["outer", "mid", "inner"]);
    }
}
